//! `lazydit serve` — the TCP JSON-lines serving front-end.
//!
//! With `--replicas N` the coordinator runs a replica pool: N worker
//! threads each owning a private engine, with `--route {rr,jsq,lazy}`
//! dispatch and pool-wide admission control. `--replica-policy
//! i=policy,...` overrides the skip policy of individual replicas, which
//! turns the server into an online A/B harness (e.g. LazyDiT gates on
//! replica 0, the never-skip DDIM baseline on replica 1). `--steal on`
//! arms pool work stealing: idle replicas pull queued jobs from the
//! sibling with the highest lazy-discounted backlog.
//!
//! `--replica-spec "lat:b1x1,thr:b8x3"` provisions a heterogeneous
//! SLO-tiered pool instead: each comma-separated group is
//! `tier:bBxN` — tier ∈ {lat, thr, be}, B the replica's max batch
//! width, N how many replicas of that shape to run. Requests carrying a
//! wire `"slo"` tag route to their tier (best-effort traffic uses
//! `--route`); the `STATS` wire verb exposes the live per-replica
//! gauges. See docs/SERVING.md for the grammar and tuning cookbook.
//!
//! `--synthetic` serves the deterministic synthetic engine instead of
//! the real model — no artifacts or XLA runtime needed; useful for
//! exercising the pool/router layer and for load drills.
//!
//! `--result-cache N` fronts the router with the content-addressable
//! cache (N-entry exact-result tier keyed on the canonical
//! `RequestKey`): a repeated request is answered with zero engine work
//! and settles the `cache_hits` ledger term. `--warm-horizon H`
//! additionally arms the warm-start donor tier — a near hit (same
//! label/cfg/steps, different seed) seeds the joiner's lane caches from
//! a donor boundary snapshot taken within the first H steps, turning
//! cold-row denials into skips. See docs/SERVING.md.
//!
//! `--calendar cal.json` loads a skip calendar profiled by `lazydit
//! calibrate`: the router prices every request in predicted module
//! invocations at admission, latency-tier requests without an explicit
//! wire deadline get one derived from predicted service time, and a
//! request that cannot meet its deadline on any replica is shed with
//! `"shed": "no_slack"`. Without the flag an online EWMA fallback
//! self-calibrates the same pricing from live traffic. `--deadline-ms`
//! makes the `--self-drive` client stamp every request with a relative
//! deadline. See docs/SERVING.md, "Deadlines & skip calendars".
//!
//! `--trace-out trace.json` arms per-replica telemetry rings
//! (`--trace-ring` events each) and writes a Chrome-trace-format file
//! at shutdown — load it in Perfetto / chrome://tracing to see one
//! track per replica with module run/skip slices (see
//! docs/OBSERVABILITY.md). The live tail of the same rings is on the
//! wire as the `TRACE` verb. `--self-drive N` generates N requests
//! against the server from an internal client — a single-process smoke
//! path (`serve --synthetic --trace-out t.json --self-drive 24`) that
//! needs no external load generator.

use crate::cli::common::{merge_specs, serve_config, EvalContext};
use crate::config::{LazyScope, RoutePolicy, ServeConfig, SkipPolicy, Slo};
use crate::coordinator::engine::{Engine, EngineOptions};
use crate::coordinator::pool::replica::{ReplicaHandle, ReplicaTier};
use crate::coordinator::pool::sim::{SimEngine, SimSpec};
use crate::coordinator::pool::{Brownout, BrownoutConfig, CacheConfig,
                               FaultEngine, FaultPlan, PoolCache,
                               PoolCalendar, PoolEngine, Rebalancer,
                               RespawnFactory, Router, SkipCalendar,
                               Supervisor, SupervisorConfig};
use crate::coordinator::server::serve_pool_shared;
use crate::util::argparse::{Args, OptSpec};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "addr", help: "bind address", default: Some("127.0.0.1:8471"), is_flag: false },
        OptSpec { name: "lazy", help: "lazy ratio % (0 = DDIM)", default: Some("50"), is_flag: false },
        OptSpec { name: "steps", help: "gate grid (training) steps", default: Some("20"), is_flag: false },
        OptSpec { name: "max-requests", help: "stop after N (0 = forever)", default: Some("0"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "both|attn|ffn|none", default: Some("both"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes per round", default: Some("8"), is_flag: false },
        OptSpec { name: "queue-cap", help: "admission bound (pool-wide)", default: Some("256"), is_flag: false },
        OptSpec { name: "result-cache", help: "exact-result cache capacity (0 = off)", default: Some("0"), is_flag: false },
        OptSpec { name: "warm-horizon", help: "warm-start donor step horizon (0 = off; needs --result-cache)", default: Some("0"), is_flag: false },
        OptSpec { name: "calendar", help: "calibrated skip-calendar artifact (from lazydit calibrate)", default: None, is_flag: false },
        OptSpec { name: "deadline-ms", help: "self-drive client: per-request deadline in ms (0 = none)", default: Some("0"), is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance scale", default: Some("1.5"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "coupled-gate", help: "legacy all-or-nothing batch skip gate (disables row-granular skipping)", default: None, is_flag: true },
        OptSpec { name: "replicas", help: "replica-pool size", default: Some("1"), is_flag: false },
        OptSpec { name: "replica-spec", help: "SLO-tiered pool, e.g. lat:b1x1,thr:b8x3 (overrides --replicas/--max-batch)", default: None, is_flag: false },
        OptSpec { name: "route", help: "dispatch policy: rr|jsq|lazy", default: Some("rr"), is_flag: false },
        OptSpec { name: "steal", help: "pool work stealing: on|off", default: Some("off"), is_flag: false },
        OptSpec { name: "replica-policy", help: "per-replica skip-policy overrides, e.g. 0=mean,1=never", default: None, is_flag: false },
        OptSpec { name: "synthetic", help: "serve the synthetic engine (no artifacts needed)", default: None, is_flag: true },
        OptSpec { name: "trace-out", help: "write a Chrome-trace JSON here at shutdown (arms telemetry)", default: None, is_flag: false },
        OptSpec { name: "trace-ring", help: "per-replica trace ring capacity (events)", default: Some("4096"), is_flag: false },
        OptSpec { name: "self-drive", help: "generate N requests from an internal client (smoke runs)", default: Some("0"), is_flag: false },
        OptSpec { name: "drain-after", help: "after N completions, drain replica 0 by migration until one trajectory moves (0 = never; needs --steal on and >= 2 replicas)", default: Some("0"), is_flag: false },
        OptSpec { name: "fault-plan", help: "deterministic fault schedule, e.g. panic@8,r1:stall@4=200,seed=7 (see docs/SERVING.md)", default: None, is_flag: false },
        OptSpec { name: "supervise", help: "replica supervision (respawn + breaker): on|off", default: Some("off"), is_flag: false },
        OptSpec { name: "brownout", help: "pool-wide overload degradation ladder: on|off", default: Some("off"), is_flag: false },
        OptSpec { name: "sim-work", help: "synthetic spin per executed module", default: Some("4000"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate training steps if needed", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate training lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
    ])
}

/// Hard cap on the pool size a `--replica-spec` may request: each
/// replica is a full worker thread + engine, so a typo like `b8x800`
/// should fail loudly instead of exhausting the machine.
const MAX_SPEC_REPLICAS: usize = 256;

/// Parse `--replica-spec "lat:b1x1,thr:b8x3"` into per-replica tiers.
///
/// Grammar: comma-separated groups of `tier:bBxN` where `tier` is an
/// SLO class (`lat`/`latency`, `thr`/`throughput`, `be`/`besteffort`),
/// `B ≥ 1` is the group's max batch width (its bucket set is the powers
/// of two below `B` plus `B` itself), and `N ≥ 1` is how many replicas
/// of that shape to provision. Groups expand in order:
/// `lat:b1x1,thr:b8x3` is replica 0 latency-tier B1 and replicas 1–3
/// throughput-tier B8. On the real engine the width must be realizable
/// by the compiled bucket set — `run` refuses the spec otherwise (see
/// docs/SERVING.md).
pub fn parse_replica_spec(spec: &str) -> Result<Vec<ReplicaTier>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (tier, shape) = part.split_once(':').with_context(|| {
            format!("bad group '{part}' (want tier:bBxN, e.g. lat:b1x1)")
        })?;
        let slo = Slo::parse(tier)
            .with_context(|| format!("bad tier in '{part}'"))?;
        let shape = shape.trim();
        let rest = shape.strip_prefix('b').with_context(|| {
            format!("bad shape '{shape}' in '{part}' (want bBxN, e.g. b8x3)")
        })?;
        let (batch, count) = rest.split_once('x').with_context(|| {
            format!("bad shape '{shape}' in '{part}' (want bBxN, e.g. b8x3)")
        })?;
        let batch: usize = batch.trim().parse().with_context(|| {
            format!("bad batch width in '{part}'")
        })?;
        let count: usize = count.trim().parse().with_context(|| {
            format!("bad replica count in '{part}'")
        })?;
        if batch == 0 {
            bail!("batch width must be >= 1 in '{part}'");
        }
        if count == 0 {
            bail!("replica count must be >= 1 in '{part}'");
        }
        // check `count` on its own first: `out.len() + count` could wrap
        // in release builds for absurd counts, skipping this very guard
        if count > MAX_SPEC_REPLICAS
            || out.len() + count > MAX_SPEC_REPLICAS
        {
            bail!("--replica-spec asks for more than {MAX_SPEC_REPLICAS} \
                   replicas");
        }
        for _ in 0..count {
            out.push(ReplicaTier::new(slo, batch));
        }
    }
    if out.is_empty() {
        bail!("--replica-spec parsed to zero replicas");
    }
    Ok(out)
}

/// Internal smoke client (`--self-drive N`): connects to the server it
/// shares a process with, sends `n` single-lane requests cycling over
/// the SLO classes, waits for each response, then exercises the `STATS`
/// and `TRACE` verbs once. Failures only log — the serve loop's own
/// `max_requests` bound decides when the process exits. `sock_stalls`
/// carries a fault plan's client-side `sock@I=MS` items: the client
/// sleeps MS ms before reading response I (a deterministic slow
/// reader, exercising the server's bounded response write).
/// `deadline_ms > 0` stamps every request with that relative deadline,
/// exercising the EDF admission path end to end.
fn self_drive_client(addr: String, n: usize, deadline_ms: u64,
                     sock_stalls: Vec<(u64, u64)>)
                     -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let mut stream = None;
        for _ in 0..200 {
            match std::net::TcpStream::connect(&addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => std::thread::sleep(
                    std::time::Duration::from_millis(50)),
            }
        }
        let Some(mut s) = stream else {
            log::warn!("self-drive: could not connect to {addr}");
            return;
        };
        let mut reader =
            BufReader::new(s.try_clone().expect("clone self-drive stream"));
        let mut line = String::new();
        let deadline = if deadline_ms > 0 {
            format!(", \"deadline_ms\": {deadline_ms}")
        } else {
            String::new()
        };
        for i in 0..n {
            let slo = ["besteffort", "latency", "throughput"][i % 3];
            let req = format!(
                "{{\"label\": {}, \"steps\": 4, \"seed\": {i}, \
                 \"cfg_scale\": 1.0, \"slo\": \"{slo}\"{deadline}}}\n",
                i % 10);
            if s.write_all(req.as_bytes()).is_err() {
                return;
            }
            if let Some((_, ms)) = sock_stalls
                .iter()
                .find(|(idx, _)| *idx == i as u64)
            {
                log::info!("self-drive: stalling {ms}ms before reading \
                            response {i}");
                std::thread::sleep(std::time::Duration::from_millis(*ms));
            }
            line.clear();
            if reader.read_line(&mut line).is_err() {
                return;
            }
        }
        for verb in ["STATS\n", "TRACE\n"] {
            if s.write_all(verb.as_bytes()).is_err() {
                return;
            }
            line.clear();
            let _ = reader.read_line(&mut line);
        }
        log::info!("self-drive: {n} requests served");
    })
}

/// FNV-1a over the model-identity descriptor — the `model_params`
/// fingerprint folded into every [`crate::coordinator::request::RequestKey`]
/// and stamped into calibrated skip calendars.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Model-identity descriptor for a `--synthetic` run. Shared with
/// `lazydit calibrate` so a calendar profiled under the same knobs
/// fingerprints identically and `serve --calendar` accepts it.
pub fn synthetic_desc(lazy_pct: usize, work: u64, coupled: bool) -> String {
    format!("sim:lazy={lazy_pct}:work={work}:coupled={coupled}")
}

/// Model-identity descriptor for a real-engine run (same contract as
/// [`synthetic_desc`]: serve and calibrate must derive the fingerprint
/// from one string).
pub fn engine_desc(model: &str, policy: &str, lazy_pct: usize,
                   steps: usize) -> String {
    format!("{model}:policy={policy}:lazy={lazy_pct}:steps={steps}")
}

/// Parse an `on|off` switch value for flag `--{name}`.
pub fn parse_switch(name: &str, v: &str) -> Result<bool> {
    match v.trim() {
        "on" => Ok(true),
        "off" => Ok(false),
        other => bail!("--{name} must be 'on' or 'off', got '{other}'"),
    }
}

/// Parse the `--steal on|off` switch.
pub fn parse_steal(v: &str) -> Result<bool> {
    parse_switch("steal", v)
}

/// Parse `--replica-policy 0=mean,2=never` into an index → policy map.
pub fn parse_replica_policies(spec: &str, replicas: usize)
                              -> Result<BTreeMap<usize, SkipPolicy>> {
    let mut out = BTreeMap::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (idx, pol) = part
            .split_once('=')
            .with_context(|| format!("bad override '{part}' (want i=policy)"))?;
        let idx: usize = idx
            .trim()
            .parse()
            .with_context(|| format!("bad replica index in '{part}'"))?;
        if idx >= replicas {
            bail!("replica index {idx} out of range (replicas = {replicas})");
        }
        let policy = SkipPolicy::parse(pol.trim())?;
        if out.insert(idx, policy).is_some() {
            bail!("duplicate override for replica {idx}");
        }
    }
    Ok(out)
}

/// Synthetic-engine factories: one per replica, policy label per
/// override. Reusable ([`RespawnFactory`]) so a supervisor can rebuild
/// a crashed replica's engine in place; a fault plan compiles into each
/// replica's [`SimSpec`] natively (zero overhead when absent).
fn synthetic_factories(replicas: usize, lazy_pct: usize, work: u64,
                       coupled: bool,
                       overrides: &BTreeMap<usize, SkipPolicy>,
                       plan: Option<&FaultPlan>)
                       -> Vec<RespawnFactory> {
    (0..replicas)
        .map(|i| {
            // run() rejects every override except "never" under
            // --synthetic, so an override here always means the
            // DDIM-baseline lane of an A/B run (Γ pinned to 0)
            let (lazy, policy) = if overrides.contains_key(&i) {
                (0, "never".to_string())
            } else {
                (lazy_pct as u32, "sim".to_string())
            };
            let spec = SimSpec {
                lazy_pct: lazy,
                work_per_module: work,
                // --coupled-gate models the legacy all-or-nothing
                // batch gate in the simulator too
                coupled,
                policy,
                ..SimSpec::default()
            };
            let plan = plan.cloned();
            let f: RespawnFactory = std::sync::Arc::new(move || {
                let mut spec = spec.clone();
                if let Some(p) = &plan {
                    // compiled fresh per incarnation: a respawned
                    // replica re-arms its round-indexed schedule, so
                    // `panic@k` under supervision produces
                    // reproducible flapping, not a one-shot crash
                    spec.faults = p.for_replica(i);
                }
                Ok(Box::new(SimEngine::new(spec)) as Box<dyn PoolEngine>)
            });
            f
        })
        .collect()
}

/// Real-engine factories. Everything captured is `Send` (plain config +
/// flat weights); each replica constructs Runtime + ModelRunner + Engine
/// on its own thread because PJRT types are `!Send`/`!Sync`. Each
/// replica's `ServeConfig` takes its tier's batch width — and, when the
/// pool was provisioned via `--replica-spec` (`tiered`), its tier's
/// bucket set — so a heterogeneous spec provisions genuinely different
/// batchers. The default uniform pool leaves the compiled bucket set
/// untouched (identical to the pre-tier behavior).
fn engine_factories(ctx: &EvalContext, serve_cfg: &ServeConfig,
                    gamma: Option<Vec<f32>>, tiers: &[ReplicaTier],
                    tiered: bool,
                    overrides: &BTreeMap<usize, SkipPolicy>,
                    plan: Option<&FaultPlan>)
                    -> Vec<RespawnFactory> {
    // share one copy of the flat weights across all factories — N
    // replicas must not mean N+1 resident copies of θ
    let theta = std::sync::Arc::new(ctx.theta.clone());
    let gamma = gamma.map(std::sync::Arc::new);
    (0..tiers.len())
        .map(|i| {
            let cfg = ctx.cfg.clone();
            let theta = theta.clone();
            let gamma = gamma.clone();
            let mut serve = serve_cfg.clone();
            serve.max_batch = tiers[i].max_batch;
            if tiered {
                serve.bucket_override = Some(tiers[i].buckets.clone());
            }
            if let Some(p) = overrides.get(&i) {
                serve.policy = *p;
            }
            let plan = plan.cloned();
            // reusable (Fn, not FnOnce): a supervised respawn rebuilds
            // Runtime + ModelRunner + Engine from the same captures
            let factory: RespawnFactory = std::sync::Arc::new(move || {
                let rt = std::rc::Rc::new(
                    crate::runtime::engine_rt::Runtime::cpu()?);
                let runner = match (&gamma, serve.policy) {
                    (Some(g), p) if p != SkipPolicy::Never => {
                        crate::model::runner::ModelRunner::new(
                            rt, cfg.clone(), &theta, g)?
                    }
                    _ => crate::model::runner::ModelRunner::with_disabled_gates(
                        rt, cfg.clone(), &theta)?,
                };
                let engine = Engine::from_parts(
                    runner, serve.clone(), EngineOptions::default());
                // the real engine has no native schedule hooks — wrap
                // it (fresh schedule per incarnation, like the sim)
                match &plan {
                    Some(p) => Ok(Box::new(FaultEngine::new(
                        Box::new(engine), p.for_replica(i)))
                        as Box<dyn PoolEngine>),
                    None => Ok(Box::new(engine) as Box<dyn PoolEngine>),
                }
            });
            factory
        })
        .collect()
}

pub fn run(a: Args) -> Result<()> {
    // pool shape: an explicit --replica-spec wins (heterogeneous,
    // SLO-tiered); otherwise --replicas uniform best-effort replicas at
    // the pool-wide --max-batch
    let tiered = a.get("replica-spec").is_some();
    let tiers: Vec<ReplicaTier> = match a.get("replica-spec") {
        Some(spec) => {
            let tiers = parse_replica_spec(&spec)?;
            if a.provided("replicas")
                && a.get_usize("replicas", 1)? != tiers.len()
            {
                bail!("--replicas {} contradicts --replica-spec '{}' \
                       ({} replicas) — drop one of the two",
                      a.get_usize("replicas", 1)?, spec, tiers.len());
            }
            tiers
        }
        None => {
            let n = a.get_usize("replicas", 1)?.max(1);
            let mb = a.get_usize("max-batch", 8)?.max(1);
            vec![ReplicaTier::new(Slo::Besteffort, mb); n]
        }
    };
    let replicas = tiers.len();
    let route = RoutePolicy::parse(&a.get_str("route", "rr"))?;
    let overrides =
        parse_replica_policies(&a.get_str("replica-policy", ""), replicas)?;
    let lazy_pct = a.get_usize("lazy", 50)?;
    let addr = a.get_str("addr", "127.0.0.1:8471");
    let trace_out = a.get("trace-out");
    let trace_ring = a.get_usize("trace-ring", 4096)?.max(2);
    let self_drive = a.get_usize("self-drive", 0)?;
    // a self-driven run must terminate on its own: the internal client
    // is the only load source, so its request count bounds the serve
    // loop unless the user asked for more explicitly
    let max_requests = match a.get_usize("max-requests", 0)? {
        0 if self_drive > 0 => self_drive,
        n => n,
    };
    let supervise = parse_switch("supervise", &a.get_str("supervise", "off"))?;
    let brownout_on = parse_switch("brownout", &a.get_str("brownout", "off"))?;
    let fault_plan = match a.get("fault-plan") {
        Some(spec) => {
            let p = FaultPlan::parse(&spec)?;
            if p.is_empty() { None } else { Some(p) }
        }
        None => None,
    };
    if let Some(p) = &fault_plan {
        if !p.sock_stalls().is_empty() && self_drive == 0 {
            bail!("--fault-plan sock@ items are client-side — they need \
                   --self-drive N to have a client to stall");
        }
    }

    // model_desc: everything that determines output identity for this
    // process, folded into every RequestKey — results cached under one
    // engine configuration can never alias another's
    let (factories, queue_cap, model_desc) = if a.flag("synthetic") {
        // the simulator only distinguishes skip-vs-never; honoring any
        // other override in name only would mislabel the A/B report
        if let Some((i, p)) =
            overrides.iter().find(|(_, &p)| p != SkipPolicy::Never)
        {
            bail!("--replica-policy {i}={} is not supported with \
                   --synthetic (only 'never' changes simulated behavior)",
                  p.name());
        }
        let work = a.get_u64("sim-work", 4000)?;
        let desc = synthetic_desc(lazy_pct, work, a.flag("coupled-gate"));
        (synthetic_factories(replicas, lazy_pct, work,
                             a.flag("coupled-gate"), &overrides,
                             fault_plan.as_ref()),
         a.get_usize("queue-cap", 256)?, desc)
    } else {
        let ctx = EvalContext::open(&a, 32)?;
        if tiered {
            // a tier's advertised width must be realizable by the
            // compiled bucket set: the router and thieves admit by
            // `max_batch`, and if the engine's effective plan cap were
            // smaller it could only serve an admitted CFG request by
            // silently stripping guidance — replica-dependent output,
            // breaking the determinism contract. Refuse the spec
            // up front instead.
            for (i, t) in tiers.iter().enumerate() {
                let usable: Vec<usize> = t
                    .buckets
                    .iter()
                    .copied()
                    .filter(|b| ctx.cfg.buckets.contains(b))
                    .collect();
                let eff = crate::coordinator::batcher::plan_cap(
                    &usable, t.max_batch);
                if eff != t.max_batch {
                    bail!("--replica-spec: replica {i} ({}:b{}) is not \
                           realizable by the compiled bucket set {:?} \
                           (effective cap {eff}) — use a compiled width",
                          t.slo.name(), t.max_batch, ctx.cfg.buckets);
                }
            }
        }
        // pool shape (--replicas/--route) lives in run()'s locals; the
        // per-engine ServeConfig stays pool-agnostic
        let mut serve_cfg = serve_config(&a, &ctx.cfg.model.name)?;
        let steps = a.get_usize("steps", 20)?;
        let gamma = if lazy_pct == 0 {
            // without trained gates only the never-skip baseline can run;
            // a non-never override would be silently mislabeled in the
            // A/B report, so refuse it outright
            if let Some((i, p)) =
                overrides.iter().find(|(_, &p)| p != SkipPolicy::Never)
            {
                bail!("--replica-policy {i}={} needs trained gates — \
                       use --lazy > 0", p.name());
            }
            None
        } else {
            Some(ctx.ensure_gates(&a, steps, lazy_pct, LazyScope::Both)?)
        };
        if lazy_pct == 0 {
            serve_cfg.policy = SkipPolicy::Never;
        }
        let qc = serve_cfg.queue_cap;
        let desc = engine_desc(&ctx.cfg.model.name, serve_cfg.policy.name(),
                               lazy_pct, steps);
        (engine_factories(&ctx, &serve_cfg, gamma, &tiers, tiered,
                          &overrides, fault_plan.as_ref()), qc, desc)
    };

    let result_cache = a.get_usize("result-cache", 0)?;
    let warm_horizon = a.get_usize("warm-horizon", 0)?;
    if warm_horizon > 0 && result_cache == 0 {
        bail!("--warm-horizon needs --result-cache > 0 (the donor store \
               shares the cache's capacity and key derivation)");
    }
    let cache = if result_cache > 0 {
        Some(std::sync::Arc::new(PoolCache::new(CacheConfig::new(
            result_cache, warm_horizon, fnv64(model_desc.as_bytes())))))
    } else {
        None
    };

    // admission pricing: an explicit --calendar artifact arms calibrated
    // per-step costs; without one the online EWMA fallback
    // self-calibrates from live traffic. A loaded artifact must
    // fingerprint-match this process's model-identity descriptor —
    // pricing with another configuration's profile would be silently
    // wrong, so refuse it up front.
    let calendar = match a.get("calendar") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading calendar {path}"))?;
            let cal = SkipCalendar::decode(&text).map_err(|e| {
                anyhow::anyhow!("calendar {path}: {e}")
            })?;
            let fp = fnv64(model_desc.as_bytes());
            if cal.model_params != fp {
                bail!("calendar {path} was profiled on model \
                       {:#018x}, this server is {fp:#018x} \
                       ({model_desc}) — re-run lazydit calibrate with \
                       matching engine flags", cal.model_params);
            }
            std::sync::Arc::new(PoolCalendar::new(Some(cal)))
        }
        None => std::sync::Arc::new(PoolCalendar::online()),
    };

    // work stealing: idle replicas pull queued jobs from the sibling
    // with the highest lazy-discounted backlog (SLO- and lane-
    // compatible jobs only). Each replica's in-engine admission window
    // comes from its own tier (`ReplicaTier::steal_window`, which
    // tracks the tier's batch width); the rebalancer's constructor
    // window is only the default for tier-less `spawn_with` callers,
    // so set it to the widest tier — a future mixed pool errs toward
    // less steal-thrash rather than a silent window of 1.
    let steal = parse_steal(&a.get_str("steal", "off"))?;
    let drain_after = a.get_usize("drain-after", 0)?;
    if drain_after > 0 && (!steal || replicas < 2) {
        bail!("--drain-after needs --steal on and at least 2 replicas \
               (a drained resident must have a sibling to migrate to)");
    }
    let rebalancer = if steal && replicas > 1 {
        let widest = tiers.iter().map(|t| t.steal_window).max().unwrap_or(8);
        Some(Rebalancer::new(widest))
    } else {
        None
    };
    // telemetry: with --trace-out each replica gets its own ring; the
    // clones kept here drain them for the Chrome export after shutdown
    // (the ring is shared through an Arc, so the replica's writes are
    // visible to this thread's reader)
    let mut tracers: Vec<crate::obs::Tracer> = Vec::with_capacity(replicas);
    let handles: Vec<ReplicaHandle> = factories
        .iter()
        .zip(tiers.iter())
        .enumerate()
        .map(|(i, (f, tier))| {
            let tracer = if trace_out.is_some() {
                crate::obs::Tracer::enabled(i, trace_ring)
            } else {
                crate::obs::Tracer::disabled()
            };
            tracers.push(tracer.clone());
            if supervise {
                ReplicaHandle::spawn_supervised(
                    i, queue_cap, f, rebalancer.clone(), tier.clone(),
                    tracer, cache.clone())
            } else {
                let f = f.clone();
                ReplicaHandle::spawn_cached(
                    i, queue_cap, Box::new(move || f()), rebalancer.clone(),
                    tier.clone(), tracer, cache.clone())
            }
        })
        .collect::<Result<_>>()?;
    let router = Router::with_cache(handles, route, queue_cap,
                                    rebalancer.clone(), cache.clone())
        .with_calendar(calendar.clone());
    let brownout_ctl = if brownout_on {
        Some(std::sync::Arc::new(Brownout::new(BrownoutConfig::default(),
                                               cache.clone())))
    } else {
        None
    };
    let router = match &brownout_ctl {
        Some(b) => router.with_brownout_controller(b.clone()),
        None => router,
    };

    let tier_summary: Vec<String> = tiers
        .iter()
        .map(|t| format!("{}:b{}", t.slo.name(), t.max_batch))
        .collect();
    println!("serving on {addr} — {replicas} replica(s) [{}], route {}, \
              steal {} — send JSON lines like {{\"label\":3,\"steps\":20,\
              \"seed\":1,\"cfg_scale\":1.0,\"slo\":\"latency\"}} \
              or the STATS verb",
             tier_summary.join(","),
             route.name(),
             if router.stealing() { "on" } else { "off" });
    if calendar.armed() {
        println!("calendar: armed — calibrated admission pricing + \
                  latency-tier deadline defaults active");
    }
    let driver = if self_drive > 0 {
        let stalls = fault_plan
            .as_ref()
            .map(|p| p.sock_stalls().to_vec())
            .unwrap_or_default();
        Some(self_drive_client(addr.clone(), self_drive,
                               a.get_u64("deadline-ms", 0)?, stalls))
    } else {
        None
    };
    let router = std::sync::Arc::new(router);
    let supervisor = if supervise {
        Some(Supervisor::new(router.clone(), factories, rebalancer,
                             cache.clone(), SupervisorConfig::default()))
    } else {
        None
    };
    let report = serve_pool_shared(router.clone(), &addr, max_requests,
                                   drain_after, supervisor,
                                   brownout_ctl.clone())?;
    if let Some(d) = driver {
        let _ = d.join();
    }
    println!("{}", report.render());
    // machine-greppable migration + ledger lines for the smoke gates:
    // every dispatched request must be accounted for — completed, shed
    // at admission, or forfeited to a panic — even across migrations.
    // All five terms come from the router's monotone gauges, NOT the
    // report: a panicked incarnation's ServeStats die with its thread,
    // so under chaos the report undercounts while the gauges (bumped
    // at completion time, before any later crash) stay exact.
    let (dispatched, completed, shed, forfeited, cache_hits) = (
        router.total_dispatched(),
        router.total_completed(),
        router.shed_count(),
        router.total_forfeited(),
        router.total_cache_hits(),
    );
    let balanced = dispatched == completed + cache_hits + shed + forfeited;
    println!("migration: out={} in={} resumed={} steps_saved={}",
             report.total_migrated_out(), report.total_migrated_in(),
             report.total_resumed(), report.total_resume_steps_saved());
    if result_cache > 0 {
        println!("cache: hits={cache_hits} warm_hits={} rows_warmed={}",
                 report.total_warm_hits(), report.total_rows_warmed());
    }
    // always printed: the deadline gauges run whether or not a calendar
    // is armed, and the smoke gates grep this line
    println!("deadline: hits={} misses={} slack_sheds={}",
             router.total_deadline_hits(), router.total_deadline_misses(),
             router.slack_shed_count());
    println!("conservation: dispatched={dispatched} completed={completed} \
              cache_hits={cache_hits} shed={shed} forfeited={forfeited} \
              ok={balanced}");
    if supervise {
        println!("supervisor: restarts={} breaker_trips={} dead={} \
                  write_timeouts={}",
                 router.total_restarts(), router.total_breaker_trips(),
                 router.dead_replicas(), router.total_write_timeouts());
    }
    if let Some(b) = &brownout_ctl {
        println!("brownout: stage={} peak={} transitions={}",
                 b.stage(), b.peak_stage(), b.transitions());
    }
    if !balanced {
        bail!("conservation violated: {dispatched} dispatched but \
               {completed} completed + {cache_hits} cache hits + {shed} \
               shed + {forfeited} forfeited — a request was stranded");
    }
    if let Some(path) = &trace_out {
        let groups = crate::obs::chrome::collect_tracers(
            &tracers, trace_ring);
        let summary = crate::obs::chrome::write_chrome_trace(
            std::path::Path::new(path), &groups)?;
        println!("trace: {} events ({} slices, {} instants) on {} \
                  track(s) -> {path}",
                 summary.events, summary.slices, summary.instants,
                 summary.tracks);
    }
    // a supervisor watching the exit code must not see success when the
    // pool never actually served anything
    if report.failed() == report.replicas.len() {
        bail!("all {} replica(s) failed — see report above",
              report.replicas.len());
    }
    // gauge-based, not report-based: under supervised chaos a crashed
    // incarnation's completions survive in the gauges even though its
    // report died with it — the pool did serve, so don't fail the run
    if report.failed() > 0 && completed == 0 {
        bail!("{} replica(s) failed and no requests were served",
              report.failed());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_policy_overrides_parse() {
        let m = parse_replica_policies("0=mean,2=never", 3).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[&0], SkipPolicy::Mean);
        assert_eq!(m[&2], SkipPolicy::Never);
        assert!(parse_replica_policies("", 1).unwrap().is_empty());
        assert!(parse_replica_policies("3=mean", 3).is_err(), "out of range");
        assert!(parse_replica_policies("0=mean,0=never", 3).is_err(),
                "duplicate index must not silently last-write-win");
        assert!(parse_replica_policies("x=mean", 3).is_err());
        assert!(parse_replica_policies("0=bogus", 3).is_err());
        assert!(parse_replica_policies("0common", 3).is_err());
    }

    #[test]
    fn replica_spec_grammar_parses() {
        let tiers = parse_replica_spec("lat:b1x1,thr:b8x3").unwrap();
        assert_eq!(tiers.len(), 4);
        assert_eq!(tiers[0].slo, Slo::Latency);
        assert_eq!(tiers[0].max_batch, 1);
        assert_eq!(tiers[0].buckets, vec![1]);
        for t in &tiers[1..] {
            assert_eq!(t.slo, Slo::Throughput);
            assert_eq!(t.max_batch, 8);
            assert_eq!(t.buckets, vec![1, 2, 4, 8]);
        }
        // long spellings, whitespace, and best-effort groups
        let tiers =
            parse_replica_spec(" latency:b2x1 , besteffort:b4x2 ").unwrap();
        assert_eq!(tiers.len(), 3);
        assert_eq!(tiers[0].slo, Slo::Latency);
        assert_eq!(tiers[2].slo, Slo::Besteffort);
    }

    #[test]
    fn replica_spec_rejects_malformed_groups() {
        for bad in [
            "",                  // zero replicas
            "lat",               // no shape
            "lat:1x1",           // missing the b prefix
            "lat:b1",            // missing the count
            "lat:bx1",           // empty batch width
            "lat:b0x1",          // zero batch width
            "lat:b1x0",          // zero count
            "gold:b1x1",         // unknown tier
            "lat:b1x1,lat:b8x999", // over the spec cap
            // a count huge enough to wrap `out.len() + count` must hit
            // the cap error, not overflow past the guard
            "lat:b1x1,thr:b8x18446744073709551615",
        ] {
            assert!(parse_replica_spec(bad).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn steal_switch_parses_strictly() {
        assert!(parse_steal("on").unwrap());
        assert!(!parse_steal("off").unwrap());
        assert!(!parse_steal(" off ").unwrap());
        assert!(parse_steal("yes").is_err());
        assert!(parse_steal("").is_err());
    }

    #[test]
    fn synthetic_factories_honor_never_override() {
        let mut ov = BTreeMap::new();
        ov.insert(1usize, SkipPolicy::Never);
        let f = synthetic_factories(2, 50, 10, false, &ov, None);
        assert_eq!(f.len(), 2);
        // factories are opaque; behavior is pinned by integration_pool
    }
}
