//! `lazydit serve` — the TCP JSON-lines serving front-end.

use crate::cli::common::{merge_specs, serve_config, EvalContext};
use crate::config::LazyScope;
use crate::coordinator::engine::EngineOptions;
use crate::coordinator::server::serve;
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "addr", help: "bind address", default: Some("127.0.0.1:8471"), is_flag: false },
        OptSpec { name: "lazy", help: "lazy ratio % (0 = DDIM)", default: Some("50"), is_flag: false },
        OptSpec { name: "steps", help: "gate grid (training) steps", default: Some("20"), is_flag: false },
        OptSpec { name: "max-requests", help: "stop after N (0 = forever)", default: Some("0"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "both|attn|ffn|none", default: Some("both"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes per round", default: Some("8"), is_flag: false },
        OptSpec { name: "queue-cap", help: "admission bound", default: Some("256"), is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance scale", default: Some("1.5"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate training steps if needed", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate training lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
    ])
}

pub fn run(a: Args) -> Result<()> {
    let ctx = EvalContext::open(&a, 32)?;
    let serve_cfg = serve_config(&a, &ctx.cfg.model.name)?;
    let lazy_pct = a.get_usize("lazy", 50)?;
    let steps = a.get_usize("steps", 20)?;
    let engine = if lazy_pct == 0 {
        ctx.engine(serve_cfg,
                   EngineOptions { disable_gates: true, ..Default::default() },
                   None)?
    } else {
        let gamma = ctx.ensure_gates(&a, steps, lazy_pct, LazyScope::Both)?;
        ctx.engine(serve_cfg, EngineOptions::default(), Some(&gamma))?
    };
    let addr = a.get_str("addr", "127.0.0.1:8471");
    let max_requests = a.get_usize("max-requests", 0)?;
    println!("serving on {addr} — send JSON lines like \
              {{\"label\":3,\"steps\":20,\"seed\":1}}");
    serve(engine, &addr, max_requests)
}
