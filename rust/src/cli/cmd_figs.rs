//! Paper-figure regenerators (Figures 4, 5, 6).

use crate::bench::quality::{eval_labels, stack_images};
use crate::cli::common::{gate_tag, merge_specs, serve_config, EvalContext};
use crate::config::{LazyScope, TrainConfig};
use crate::coordinator::engine::{generate_batch, EngineOptions};
use crate::io::table::TableWriter;
use crate::model::checkpoint::{gates_path, Checkpoint};
use crate::train::lazytrain::{lazy_train, LazyTrainOptions};
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "steps", help: "sampling steps", default: Some("20"), is_flag: false },
        OptSpec { name: "lazy", help: "lazy ratio % for fig4/fig6", default: Some("50"), is_flag: false },
        OptSpec { name: "n-eval", help: "images per point", default: Some("64"), is_flag: false },
        OptSpec { name: "n-real", help: "real reference samples", default: Some("256"), is_flag: false },
        OptSpec { name: "seed", help: "rng seed", default: Some("0"), is_flag: false },
        OptSpec { name: "part", help: "fig5: upper|lower", default: Some("upper"), is_flag: false },
        OptSpec { name: "ratios", help: "fig5 ratio grid %", default: Some("10,20,30,40,50"), is_flag: false },
        OptSpec { name: "fixed-ratio", help: "fig5 lower: fixed module ratio %", default: Some("30"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes", default: Some("16"), is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance", default: Some("1.5"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "serving lazy scope", default: Some("both"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "queue-cap", help: "queue bound", default: Some("256"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate train steps", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate train lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
        OptSpec { name: "csv", help: "also write CSV", default: None, is_flag: false },
    ])
}

/// Figure 4: per-layer laziness distribution over a 20-step run.
pub fn run_fig4(a: Args) -> Result<()> {
    let ctx = EvalContext::open(&a, 64)?;
    let steps = a.get_usize("steps", 20)?;
    let lazy_pct = a.get_usize("lazy", 50)?;
    let gamma = ctx.ensure_gates(&a, steps, lazy_pct, LazyScope::Both)?;
    let serve = serve_config(&a, &ctx.cfg.model.name)?;
    let mut engine = ctx.engine(serve, EngineOptions::default(), Some(&gamma))?;

    // paper: 8 images over 20 steps on DiT-XL
    let labels = eval_labels(8, ctx.cfg.model.num_classes);
    let cfg_scale = engine.serve.cfg_scale;
    let _ = generate_batch(&mut engine, &labels, steps, a.get_u64("seed", 0)?,
                           cfg_scale)?;
    println!("{}", engine.layer_stats.render_fig4());
    // row-weighted, like the per-module components — mixing in the
    // module-boolean ratio here could print an "overall" below both of
    // its own parts under partial (row-granular) skips
    println!("overall lazy ratio: {:.1}% (attn {:.1}%, ffn {:.1}%)",
             100.0 * engine.layer_stats.row_overall_ratio(),
             100.0 * engine.layer_stats.attn_overall(),
             100.0 * engine.layer_stats.ffn_overall());
    // no-layer-fully-bypassed check (paper's Fig. 4 observation)
    let depth = engine.layer_stats.depth();
    let fully = (0..depth).any(|l| {
        engine.layer_stats.attn_ratio(l) >= 1.0
            || engine.layer_stats.ffn_ratio(l) >= 1.0
    });
    println!("any layer 100% lazy (would justify layer removal): {fully}");

    if let Some(csv) = a.get("csv") {
        let mut t = TableWriter::new("fig4", &["layer", "attn_lazy", "ffn_lazy"]);
        for l in 0..depth {
            t.row(vec![
                l.to_string(),
                format!("{:.4}", engine.layer_stats.attn_ratio(l)),
                format!("{:.4}", engine.layer_stats.ffn_ratio(l)),
            ]);
        }
        t.write_csv(std::path::Path::new(&csv))?;
        println!("wrote {csv}");
    }
    Ok(())
}

/// Figure 5: penalty/laziness ablations.
/// upper — individual laziness: train attn-only / ffn-only gates across the
/// ratio grid and measure quality (max applicable laziness per module).
/// lower — lazy strategy: fix one module's target, sweep the other.
pub fn run_fig5(a: Args) -> Result<()> {
    let n_real = a.get_usize("n-real", 256)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let steps = a.get_usize("steps", 20)?;
    let ratios = a.get_usize_list("ratios", &[10, 20, 30, 40, 50])?;
    let part = a.get_str("part", "upper");
    let n_eval = a.get_usize("n-eval", 64)?;
    let seed = a.get_u64("seed", 0)?;

    let mut t = TableWriter::new(
        &format!("Figure 5 ({part}) — {} @ {steps} steps", ctx.cfg.model.name),
        &["setting", "target", "achieved attn", "achieved ffn", "FID-a ↓",
          "IS-a ↑"],
    );

    let settings: Vec<(String, LazyScope, Option<usize>, usize)> = match part.as_str() {
        "upper" => {
            let mut v = Vec::new();
            for &r in &ratios {
                v.push((format!("MHSA-only {r}%"), LazyScope::AttnOnly, None, r));
                v.push((format!("FFN-only {r}%"), LazyScope::FfnOnly, None, r));
            }
            v
        }
        "lower" => {
            let fixed = a.get_usize("fixed-ratio", 30)?;
            let mut v = Vec::new();
            for &r in &ratios {
                v.push((format!("attn={fixed}% ffn={r}%"), LazyScope::Both,
                        Some(fixed), r));
            }
            for &r in &ratios {
                v.push((format!("ffn={fixed}% attn={r}%"), LazyScope::Both,
                        Some(fixed + 1000), r)); // 1000+ marks "fixed is ffn"
            }
            v
        }
        other => anyhow::bail!("unknown --part '{other}'"),
    };

    for (label, scope, fixed, ratio) in settings {
        let (ta, tf, tag) = match (part.as_str(), fixed) {
            ("upper", _) => {
                let r = Some(ratio as f64 / 100.0);
                match scope {
                    LazyScope::AttnOnly => (r, None, gate_tag(steps, ratio, scope)),
                    LazyScope::FfnOnly => (None, r, gate_tag(steps, ratio, scope)),
                    _ => unreachable!(),
                }
            }
            (_, Some(f)) if f >= 1000 => (
                Some(ratio as f64 / 100.0),
                Some((f - 1000) as f64 / 100.0),
                format!("s{steps}-a{ratio}-f{}", f - 1000),
            ),
            (_, Some(f)) => (
                Some(f as f64 / 100.0),
                Some(ratio as f64 / 100.0),
                format!("s{steps}-a{f}-f{ratio}"),
            ),
            _ => unreachable!(),
        };
        let gamma = ensure_gates_custom(&ctx, &a, steps, ta, tf, scope, &tag)?;
        let serve = serve_config(&a, &ctx.cfg.model.name)?;
        let mut engine = ctx.engine(serve, EngineOptions::default(), Some(&gamma))?;
        let labels = eval_labels(n_eval, ctx.cfg.model.num_classes);
        let cfg_scale = engine.serve.cfg_scale;
        let results = generate_batch(&mut engine, &labels, steps, seed,
                                     cfg_scale)?;
        let images = stack_images(&results)?;
        let q = ctx.metrics.evaluate(&ctx.extractor, &images)?;
        t.row(vec![
            label,
            format!("{ratio}%"),
            format!("{:.1}%", 100.0 * engine.layer_stats.attn_overall()),
            format!("{:.1}%", 100.0 * engine.layer_stats.ffn_overall()),
            format!("{:.3}", q.fid),
            format!("{:.3}", q.is),
        ]);
    }
    t.print();
    if let Some(csv) = a.get("csv") {
        t.write_csv(std::path::Path::new(&csv))?;
    }
    Ok(())
}

/// Figure 6: with jointly-trained gates, skip only MHSA or only FFN at
/// inference (serving-scope mask).
pub fn run_fig6(a: Args) -> Result<()> {
    let n_real = a.get_usize("n-real", 256)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let steps = a.get_usize("steps", 20)?;
    let lazy_pct = a.get_usize("lazy", 50)?;
    let n_eval = a.get_usize("n-eval", 64)?;
    let seed = a.get_u64("seed", 0)?;
    let gamma = ctx.ensure_gates(&a, steps, lazy_pct, LazyScope::Both)?;

    let mut t = TableWriter::new(
        &format!("Figure 6 — skip-one-module with joint gates, {} @ {steps} \
                  steps, target {lazy_pct}%", ctx.cfg.model.name),
        &["inference scope", "achieved lazy", "FID-a ↓", "IS-a ↑", "Prec ↑",
          "Rec ↑"],
    );
    for (name, scope) in [("both", LazyScope::Both),
                          ("MHSA only", LazyScope::AttnOnly),
                          ("FFN only", LazyScope::FfnOnly),
                          ("none (DDIM path)", LazyScope::None)] {
        let mut serve = serve_config(&a, &ctx.cfg.model.name)?;
        serve.scope = scope;
        let mut engine = ctx.engine(serve, EngineOptions::default(), Some(&gamma))?;
        let labels = eval_labels(n_eval, ctx.cfg.model.num_classes);
        let cfg_scale = engine.serve.cfg_scale;
        let results = generate_batch(&mut engine, &labels, steps, seed,
                                     cfg_scale)?;
        let images = stack_images(&results)?;
        let q = ctx.metrics.evaluate(&ctx.extractor, &images)?;
        let lazy: f64 = results.iter().map(|r| r.lazy_ratio).sum::<f64>()
            / results.len() as f64;
        t.row(vec![
            name.to_string(),
            format!("{:.1}%", 100.0 * lazy),
            format!("{:.3}", q.fid),
            format!("{:.3}", q.is),
            format!("{:.3}", q.precision),
            format!("{:.3}", q.recall),
        ]);
    }
    t.print();
    if let Some(csv) = a.get("csv") {
        t.write_csv(std::path::Path::new(&csv))?;
    }
    Ok(())
}

/// Train gates with custom per-module targets (fig5 support).
fn ensure_gates_custom(ctx: &EvalContext, a: &Args, steps: usize,
                       target_attn: Option<f64>, target_ffn: Option<f64>,
                       scope: LazyScope, tag: &str) -> Result<Vec<f32>> {
    let path = gates_path(&ctx.ckpt, &ctx.cfg.model.name, tag);
    if let Ok(ck) = Checkpoint::load(&path) {
        return Ok(ck.vec("gamma")?.clone());
    }
    let tc = TrainConfig {
        config_name: ctx.cfg.model.name.clone(),
        steps: a.get_usize("train-steps", 200)?,
        lr: a.get_f32("train-lr", 5e-3)?,
        ..Default::default()
    };
    let opts = LazyTrainOptions {
        serve_steps: steps,
        target_attn,
        target_ffn,
        scope,
        tag: tag.to_string(),
        adjust_every: 10,
    };
    lazy_train(&ctx.rt, &ctx.cfg, &tc, &opts, &ctx.theta, &ctx.ckpt)?;
    let ck = Checkpoint::load(&path)?;
    Ok(ck.vec("gamma")?.clone())
}
