//! `lazydit pretrain` / `lazydit lazy-train` — the two training phases.

use crate::cli::common::{artifacts_dir, ckpt_dir, config_name, load_or_pretrain,
                         merge_specs};
use crate::config::{LazyScope, TrainConfig};
use crate::runtime::engine_rt::Runtime;
use crate::runtime::manifest::Manifest;
use crate::train::lazytrain::{lazy_train, LazyTrainOptions};
use crate::train::pretrain::pretrain;
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;
use std::rc::Rc;

pub fn pretrain_specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "steps", help: "training steps", default: Some("1500"), is_flag: false },
        OptSpec { name: "lr", help: "learning rate", default: Some("2e-3"), is_flag: false },
        OptSpec { name: "seed", help: "rng seed", default: Some("0"), is_flag: false },
        OptSpec { name: "force", help: "retrain even if checkpoint exists", default: None, is_flag: true },
    ])
}

pub fn run_pretrain(a: Args) -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir(&a))?;
    let cfg = manifest.config(&config_name(&a))?.clone();
    let ckpt = ckpt_dir(&a);
    let rt = Rc::new(Runtime::cpu()?);
    let path = crate::model::checkpoint::theta_path(&ckpt, &cfg.model.name);
    if path.exists() && !a.flag("force") {
        println!("checkpoint {} exists (use --force to retrain)", path.display());
        return Ok(());
    }
    let tc = TrainConfig {
        config_name: cfg.model.name.clone(),
        steps: a.get_usize("steps", 1500)?,
        lr: a.get_f32("lr", 2e-3)?,
        seed: a.get_u64("seed", 0)?,
        ..Default::default()
    };
    let report = pretrain(&rt, &cfg, &tc, &ckpt)?;
    println!(
        "pretrained {} for {} steps in {:.1}s: loss {:.4} → {:.4} (tail {:.4})",
        cfg.model.name, report.steps, report.wall_s, report.first_loss,
        report.last_loss, report.tail_loss
    );
    Ok(())
}

pub fn lazy_specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "steps", help: "gate training steps (paper: 500)", default: Some("500"), is_flag: false },
        OptSpec { name: "lr", help: "learning rate (paper: 1e-4; tiny models like higher)", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "serve-steps", help: "sampling grid the gates serve", default: Some("20"), is_flag: false },
        OptSpec { name: "target-ratio", help: "target lazy ratio %, adaptive rho", default: Some("50"), is_flag: false },
        OptSpec { name: "rho", help: "fixed rho (disables the controller)", default: None, is_flag: false },
        OptSpec { name: "scope", help: "both|attn|ffn", default: Some("both"), is_flag: false },
        OptSpec { name: "tag", help: "checkpoint tag override", default: None, is_flag: false },
        OptSpec { name: "seed", help: "rng seed", default: Some("0"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "steps if base must be trained", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "lr if base must be trained", default: Some("2e-3"), is_flag: false },
    ])
}

pub fn run_lazy(a: Args) -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir(&a))?;
    let cfg = manifest.config(&config_name(&a))?.clone();
    let ckpt = ckpt_dir(&a);
    let rt = Rc::new(Runtime::cpu()?);
    let theta = load_or_pretrain(&rt, &cfg, &ckpt, &a)?;

    let scope = LazyScope::parse(&a.get_str("scope", "both"))?;
    let serve_steps = a.get_usize("serve-steps", 20)?;
    let ratio_pct = a.get_usize("target-ratio", 50)?;
    let fixed_rho = a.get("rho").map(|s| s.parse::<f32>()).transpose()?;
    let tag = a
        .get("tag")
        .unwrap_or_else(|| crate::cli::common::gate_tag(serve_steps, ratio_pct, scope));

    let tc = TrainConfig {
        config_name: cfg.model.name.clone(),
        steps: a.get_usize("steps", 500)?,
        lr: a.get_f32("lr", 5e-3)?,
        seed: a.get_u64("seed", 0)?,
        rho_attn: fixed_rho.unwrap_or(1e-3),
        rho_ffn: fixed_rho.unwrap_or(1e-3),
        ..Default::default()
    };
    let target = if fixed_rho.is_some() {
        None
    } else {
        Some(ratio_pct as f64 / 100.0)
    };
    let opts = LazyTrainOptions {
        serve_steps,
        target_attn: target,
        target_ffn: target,
        scope,
        tag: tag.clone(),
        adjust_every: 10,
    };
    let report = lazy_train(&rt, &cfg, &tc, &opts, &theta, &ckpt)?;
    println!(
        "lazy-trained {tag} in {:.1}s: dloss {:.4}, train-time skip frac \
         attn {:.2} ffn {:.2}, mean s attn/ffn {:.3}/{:.3}, final rho \
         {:.2e}/{:.2e}",
        report.wall_s, report.final_dloss, report.final_frac_attn,
        report.final_frac_ffn, report.mean_s_attn, report.mean_s_ffn,
        report.final_rho_attn, report.final_rho_ffn
    );
    Ok(())
}
