//! Paper-table regenerators (Tables 1, 2, 3, 5, 6, 7). Shared harness:
//! each row = one (method, steps, lazy-ratio) setting evaluated on a
//! freshly generated image set with the quality metrics + the analytic
//! TMACs model + measured wall-clock.

use crate::baselines::learn2cache::{build_schedule, schedule_ratio, SimProfile};
use crate::bench::quality::{eval_labels, stack_images, QualityRow};
use crate::cli::common::{merge_specs, serve_config, EvalContext};
use crate::config::LazyScope;
use crate::coordinator::engine::{generate_batch, EngineOptions};
use crate::io::table::TableWriter;
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "n-eval", help: "images per trial", default: Some("96"), is_flag: false },
        OptSpec { name: "n-real", help: "real reference samples", default: Some("256"), is_flag: false },
        OptSpec { name: "seed", help: "rng seed", default: Some("0"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes per round", default: Some("16"), is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance", default: Some("1.5"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "lazy scope", default: Some("both"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "queue-cap", help: "queue bound", default: Some("256"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate train steps if needed", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate train lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
        OptSpec { name: "csv", help: "also write CSV to this path", default: None, is_flag: false },
        OptSpec { name: "quick", help: "reduced row set", default: None, is_flag: true },
    ])
}

/// One table row's sampling method.
#[derive(Debug, Clone, Copy)]
pub enum Method {
    Ddim { steps: usize },
    Ours { steps: usize, ratio_pct: usize },
    L2c { steps: usize, ratio_pct: usize },
}

impl Method {
    fn label(&self) -> String {
        match self {
            Method::Ddim { .. } => "DDIM".into(),
            Method::Ours { .. } => "Ours".into(),
            Method::L2c { .. } => "Learn2Cache-a".into(),
        }
    }

    fn steps(&self) -> usize {
        match *self {
            Method::Ddim { steps } => steps,
            Method::Ours { steps, .. } => steps,
            Method::L2c { steps, .. } => steps,
        }
    }

    fn ratio_label(&self) -> String {
        match *self {
            Method::Ddim { .. } => "/".into(),
            Method::Ours { ratio_pct, .. } | Method::L2c { ratio_pct, .. } => {
                format!("{ratio_pct}%")
            }
        }
    }
}

/// A computed row.
pub struct RowResult {
    pub method: Method,
    pub quality: QualityRow,
    pub achieved_lazy: f64,
    pub gmacs_per_img: f64,
    pub wall_s: f64,
    pub latency_per_img_s: f64,
}

/// Evaluate one setting end-to-end.
pub fn run_setting(ctx: &EvalContext, a: &Args, method: Method, n_eval: usize)
                   -> Result<RowResult> {
    let serve = serve_config(a, &ctx.cfg.model.name)?;
    let steps = method.steps();
    let seed = a.get_u64("seed", 0)?;
    let cfg_scale = serve.cfg_scale;

    let mut engine = match method {
        Method::Ddim { .. } => ctx.engine(
            serve, EngineOptions { disable_gates: true, ..Default::default() },
            None)?,
        Method::Ours { ratio_pct, .. } => {
            let gamma = ctx.ensure_gates(a, steps, ratio_pct, LazyScope::Both)?;
            // serve-time threshold calibration: batch-aggregated decisions
            // overshoot the per-sample train-time fraction, so bisect the
            // gate threshold until the achieved lazy ratio matches the
            // row's target (coordinator feature; gates stay fixed).
            let mut serve = serve;
            serve.threshold = calibrate_threshold(
                ctx, &serve, &gamma, steps, ratio_pct as f64 / 100.0, seed)?;
            ctx.engine(serve, EngineOptions::default(), Some(&gamma))?
        }
        Method::L2c { ratio_pct, .. } => {
            // offline profiling pass (input-independent schedule)
            let mut prof_engine = ctx.engine(
                serve.clone(),
                EngineOptions { disable_gates: true, ..Default::default() },
                None)?;
            prof_engine.sim_profile = Some(SimProfile::new(
                steps, 2 * ctx.cfg.model.depth));
            let labels = eval_labels(8, ctx.cfg.model.num_classes);
            let _ = generate_batch(&mut prof_engine, &labels, steps,
                                   seed ^ 0x12C0, cfg_scale)?;
            let prof = prof_engine.sim_profile.take().unwrap();
            let sched = build_schedule(&prof, ratio_pct as f64 / 100.0);
            log::info!("L2C schedule: target {}% achieved {:.1}%", ratio_pct,
                       100.0 * schedule_ratio(&sched));
            ctx.engine(serve,
                       EngineOptions { disable_gates: true,
                                       static_schedule: Some(sched) },
                       None)?
        }
    };

    let labels = eval_labels(n_eval, ctx.cfg.model.num_classes);
    let t0 = std::time::Instant::now();
    let results = generate_batch(&mut engine, &labels, steps, seed, cfg_scale)?;
    let wall = t0.elapsed().as_secs_f64();
    let images = stack_images(&results)?;
    let quality = ctx.metrics.evaluate(&ctx.extractor, &images)?;
    let achieved: f64 = results.iter().map(|r| r.lazy_ratio).sum::<f64>()
        / results.len() as f64;
    let with_gates = matches!(method, Method::Ours { .. });
    let macs = crate::tmacs::run_macs(&ctx.cfg.model, steps, achieved, true,
                                      with_gates);
    Ok(RowResult {
        method,
        quality,
        achieved_lazy: achieved,
        gmacs_per_img: crate::tmacs::as_gmacs(macs),
        wall_s: wall,
        latency_per_img_s: wall / n_eval as f64,
    })
}

/// Bisect the gate threshold so the achieved lazy ratio on a small probe
/// run lands within ±4% of `target`. Returns the calibrated threshold.
pub fn calibrate_threshold(ctx: &EvalContext, serve: &crate::config::ServeConfig,
                           gamma: &[f32], steps: usize, target: f64,
                           seed: u64) -> Result<f32> {
    let (mut lo, mut hi) = (0.3f32, 0.995f32);
    let mut best = serve.threshold;
    for _ in 0..3 {
        let mid = 0.5 * (lo + hi);
        let mut s = serve.clone();
        s.threshold = mid;
        let mut engine = ctx.engine(s, EngineOptions::default(), Some(gamma))?;
        let labels = eval_labels(6, ctx.cfg.model.num_classes);
        let res = generate_batch(&mut engine, &labels, steps, seed ^ 0xCA1,
                                 serve.cfg_scale)?;
        let achieved: f64 = res.iter().map(|r| r.lazy_ratio).sum::<f64>()
            / res.len() as f64;
        best = mid;
        if (achieved - target).abs() < 0.04 {
            break;
        }
        if achieved > target {
            lo = mid; // too lazy → raise the bar
        } else {
            hi = mid;
        }
    }
    log::info!("calibrated threshold {best:.3} for target {:.0}%",
               100.0 * target);
    Ok(best)
}

fn quality_table(title: &str, ctx: &EvalContext, a: &Args,
                 rows: &[Method]) -> Result<TableWriter> {
    let n_eval = a.get_usize("n-eval", 96)?;
    let mut t = TableWriter::new(
        title,
        &["Method", "# of Step", "Lazy Ratio", "FID-a ↓", "sFID-a ↓",
          "IS-a ↑", "Prec ↑", "Rec ↑", "GMACs/img"],
    );
    for (i, &m) in rows.iter().enumerate() {
        let r = run_setting(ctx, a, m, n_eval)?;
        t.row(vec![
            m.label(),
            m.steps().to_string(),
            if matches!(m, Method::Ddim { .. }) {
                "/".into()
            } else {
                format!("{} ({:.0}%)", m.ratio_label(), 100.0 * r.achieved_lazy)
            },
            format!("{:.3}", r.quality.fid),
            format!("{:.3}", r.quality.sfid),
            format!("{:.3}", r.quality.is),
            format!("{:.3}", r.quality.precision),
            format!("{:.3}", r.quality.recall),
            format!("{:.3}", r.gmacs_per_img),
        ]);
        // paper groups DDIM/Ours pairs with separators
        if i % 2 == 1 && i + 1 < rows.len() {
            t.hline();
        }
        log::info!("{title}: finished row {}/{}", i + 1, rows.len());
    }
    Ok(t)
}

fn finish(t: TableWriter, a: &Args) -> Result<()> {
    t.print();
    if let Some(csv) = a.get("csv") {
        t.write_csv(std::path::Path::new(&csv))?;
        println!("wrote {csv}");
    }
    Ok(())
}

/// Paper Table 1 row plan (both DiT-XL analogs use the same plan).
pub fn table1_rows(quick: bool) -> Vec<Method> {
    if quick {
        vec![
            Method::Ddim { steps: 25 },
            Method::Ours { steps: 50, ratio_pct: 50 },
            Method::Ddim { steps: 10 },
            Method::Ours { steps: 20, ratio_pct: 50 },
        ]
    } else {
        vec![
            Method::Ddim { steps: 50 },
            Method::Ddim { steps: 40 },
            Method::Ours { steps: 50, ratio_pct: 20 },
            Method::Ddim { steps: 25 },
            Method::Ours { steps: 50, ratio_pct: 50 },
            Method::Ddim { steps: 20 },
            Method::Ours { steps: 40, ratio_pct: 50 },
            Method::Ddim { steps: 14 },
            Method::Ours { steps: 20, ratio_pct: 30 },
            Method::Ddim { steps: 10 },
            Method::Ours { steps: 20, ratio_pct: 50 },
            Method::Ddim { steps: 7 },
            Method::Ours { steps: 10, ratio_pct: 30 },
        ]
    }
}

pub fn run_table1(a: Args) -> Result<()> {
    let n_real = a.get_usize("n-real", 256)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let rows = table1_rows(a.flag("quick"));
    let t = quality_table(
        &format!("Table 1 — {} ({}) vs DDIM on SynthBlobs-10 (cfg=1.5)",
                 ctx.cfg.model.name, ctx.cfg.model.paper_analog),
        &ctx, &a, &rows)?;
    finish(t, &a)
}

pub fn run_table2(a: Args) -> Result<()> {
    // Large-DiT analogs: default to l3b-a unless --config given.
    let mut a = a;
    if !a.provided("config") {
        a.set("config", "l3b-a");
    }
    let n_real = a.get_usize("n-real", 256)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let rows = if a.flag("quick") {
        vec![
            Method::Ddim { steps: 25 },
            Method::Ours { steps: 50, ratio_pct: 50 },
            Method::Ddim { steps: 10 },
            Method::Ours { steps: 20, ratio_pct: 50 },
        ]
    } else {
        vec![
            Method::Ddim { steps: 50 },
            Method::Ddim { steps: 35 },
            Method::Ours { steps: 50, ratio_pct: 30 },
            Method::Ddim { steps: 25 },
            Method::Ours { steps: 50, ratio_pct: 50 },
            Method::Ddim { steps: 14 },
            Method::Ours { steps: 20, ratio_pct: 30 },
            Method::Ddim { steps: 10 },
            Method::Ours { steps: 20, ratio_pct: 50 },
        ]
    };
    let t = quality_table(
        &format!("Table 2 — {} ({}) vs DDIM (cfg=1.5)", ctx.cfg.model.name,
                 ctx.cfg.model.paper_analog),
        &ctx, &a, &rows)?;
    finish(t, &a)
}

pub fn run_table5(a: Args) -> Result<()> {
    let n_real = a.get_usize("n-real", 256)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let rows = if a.flag("quick") {
        table1_rows(true)
    } else {
        vec![
            Method::Ddim { steps: 50 },
            Method::Ddim { steps: 40 },
            Method::Ours { steps: 50, ratio_pct: 20 },
            Method::Ddim { steps: 35 },
            Method::Ours { steps: 50, ratio_pct: 30 },
            Method::Ddim { steps: 30 },
            Method::Ours { steps: 50, ratio_pct: 40 },
            Method::Ddim { steps: 25 },
            Method::Ours { steps: 50, ratio_pct: 50 },
            Method::Ddim { steps: 18 },
            Method::Ours { steps: 20, ratio_pct: 10 },
            Method::Ddim { steps: 16 },
            Method::Ours { steps: 20, ratio_pct: 20 },
            Method::Ddim { steps: 14 },
            Method::Ours { steps: 20, ratio_pct: 30 },
            Method::Ddim { steps: 10 },
            Method::Ours { steps: 20, ratio_pct: 50 },
            Method::Ddim { steps: 8 },
            Method::Ours { steps: 10, ratio_pct: 20 },
            Method::Ddim { steps: 7 },
            Method::Ours { steps: 10, ratio_pct: 30 },
            Method::Ddim { steps: 5 },
            Method::Ours { steps: 10, ratio_pct: 50 },
        ]
    };
    let t = quality_table(
        &format!("Table 5 — full sweep, {} ({})", ctx.cfg.model.name,
                 ctx.cfg.model.paper_analog),
        &ctx, &a, &rows)?;
    finish(t, &a)
}

/// Latency tables: Table 3 (mobile analog, single-stream) and Table 6
/// (GPU analog, batched). The latency column is measured end-to-end wall
/// clock per image on this engine.
fn latency_table(title: &str, ctx: &EvalContext, a: &Args, rows: &[Method],
                 n_eval: usize) -> Result<TableWriter> {
    let mut t = TableWriter::new(
        title,
        &["Method", "# of Step", "Lazy Ratio", "GMACs/img", "IS-a ↑",
          "Latency (s/img)", "Speedup vs DDIM50"],
    );
    let mut base_latency = None;
    for (i, &m) in rows.iter().enumerate() {
        let r = run_setting(ctx, a, m, n_eval)?;
        if i == 0 {
            base_latency = Some(r.latency_per_img_s);
        }
        t.row(vec![
            m.label(),
            m.steps().to_string(),
            m.ratio_label(),
            format!("{:.3}", r.gmacs_per_img),
            format!("{:.3}", r.quality.is),
            format!("{:.3}", r.latency_per_img_s),
            format!("{:.2}x",
                    base_latency.unwrap() / r.latency_per_img_s.max(1e-12)),
        ]);
        log::info!("{title}: finished row {}/{}", i + 1, rows.len());
    }
    Ok(t)
}

fn latency_rows(quick: bool) -> Vec<Method> {
    if quick {
        vec![
            Method::Ddim { steps: 25 },
            Method::Ours { steps: 50, ratio_pct: 50 },
        ]
    } else {
        vec![
            Method::Ddim { steps: 50 },
            Method::Ddim { steps: 40 },
            Method::Ddim { steps: 25 },
            Method::Ours { steps: 50, ratio_pct: 50 },
            Method::Ddim { steps: 20 },
            Method::Ddim { steps: 16 },
            Method::Ours { steps: 20, ratio_pct: 20 },
            Method::Ddim { steps: 8 },
            Method::Ddim { steps: 7 },
            Method::Ours { steps: 10, ratio_pct: 30 },
        ]
    }
}

pub fn run_table3(a: Args) -> Result<()> {
    // mobile analog: single-stream — exactly one CFG request in flight
    let mut a = a;
    if !a.provided("max-batch") {
        a.set("max-batch", "2");
    }
    let n_real = a.get_usize("n-real", 128)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let n_eval = a.get_usize("n-eval", 24)?;
    let rows = latency_rows(a.flag("quick"));
    let t = latency_table(
        &format!("Table 3 — single-stream latency (mobile analog), {}",
                 ctx.cfg.model.name),
        &ctx, &a, &rows, n_eval)?;
    finish(t, &a)
}

pub fn run_table6(a: Args) -> Result<()> {
    // GPU analog: batched serving (8 images = 16 lanes)
    let mut a = a;
    if !a.provided("max-batch") {
        a.set("max-batch", "16");
    }
    let n_real = a.get_usize("n-real", 128)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let n_eval = a.get_usize("n-eval", 32)?;
    let rows = latency_rows(a.flag("quick"));
    let t = latency_table(
        &format!("Table 6 — batched latency (A5000 analog, 8 img/batch), {}",
                 ctx.cfg.model.name),
        &ctx, &a, &rows, n_eval)?;
    finish(t, &a)
}

pub fn run_table7(a: Args) -> Result<()> {
    let n_real = a.get_usize("n-real", 256)?;
    let ctx = EvalContext::open(&a, n_real)?;
    let rows = if a.flag("quick") {
        vec![
            Method::Ddim { steps: 16 },
            Method::L2c { steps: 20, ratio_pct: 20 },
            Method::Ours { steps: 20, ratio_pct: 20 },
        ]
    } else {
        vec![
            Method::Ddim { steps: 50 },
            Method::Ddim { steps: 40 },
            Method::L2c { steps: 50, ratio_pct: 20 },
            Method::Ours { steps: 50, ratio_pct: 20 },
            Method::Ddim { steps: 16 },
            Method::L2c { steps: 20, ratio_pct: 20 },
            Method::Ours { steps: 20, ratio_pct: 20 },
            Method::Ddim { steps: 9 },
            Method::L2c { steps: 10, ratio_pct: 10 },
            Method::Ours { steps: 10, ratio_pct: 10 },
        ]
    };
    let t = quality_table(
        &format!("Table 7 — vs input-independent caching (Learn2Cache \
                  analog), {}", ctx.cfg.model.name),
        &ctx, &a, &rows)?;
    finish(t, &a)
}
