//! Command-line interface: one subcommand per workflow, including a
//! regenerator for every paper table and figure (DESIGN.md §6).

pub mod common;
pub mod cmd_info;
pub mod cmd_train;
pub mod cmd_generate;
pub mod cmd_serve;
pub mod cmd_calibrate;
pub mod cmd_eval;
pub mod cmd_tables;
pub mod cmd_figs;
pub mod cmd_profile;

use crate::util::argparse::Args;
use anyhow::{bail, Result};

/// Dispatch argv to a subcommand. argv excludes the program name.
pub fn dispatch(argv: &[String]) -> Result<()> {
    crate::util::logging::init();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "info" => cmd_info::run(parse(rest, &cmd_info::specs())?),
        "pretrain" => cmd_train::run_pretrain(parse(rest, &cmd_train::pretrain_specs())?),
        "lazy-train" => cmd_train::run_lazy(parse(rest, &cmd_train::lazy_specs())?),
        "generate" => cmd_generate::run(parse(rest, &cmd_generate::specs())?),
        "serve" => cmd_serve::run(parse(rest, &cmd_serve::specs())?),
        "calibrate" => cmd_calibrate::run(parse(rest, &cmd_calibrate::specs())?),
        "eval" => cmd_eval::run(parse(rest, &cmd_eval::specs())?),
        "table1" => cmd_tables::run_table1(parse(rest, &cmd_tables::specs())?),
        "table2" => cmd_tables::run_table2(parse(rest, &cmd_tables::specs())?),
        "table5" => cmd_tables::run_table5(parse(rest, &cmd_tables::specs())?),
        "table3" => cmd_tables::run_table3(parse(rest, &cmd_tables::specs())?),
        "table6" => cmd_tables::run_table6(parse(rest, &cmd_tables::specs())?),
        "table7" => cmd_tables::run_table7(parse(rest, &cmd_tables::specs())?),
        "fig4" => cmd_figs::run_fig4(parse(rest, &cmd_figs::specs())?),
        "fig5" => cmd_figs::run_fig5(parse(rest, &cmd_figs::specs())?),
        "fig6" => cmd_figs::run_fig6(parse(rest, &cmd_figs::specs())?),
        "profile" => cmd_profile::run(parse(rest, &cmd_profile::specs())?),
        "trace-check" => run_trace_check(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' — try `lazydit help`"),
    }
}

fn parse(rest: &[String], specs: &[crate::util::argparse::OptSpec]) -> Result<Args> {
    Args::parse(rest, specs)
}

/// `lazydit trace-check <file.json>` — structurally validate a
/// Chrome-trace file written by `serve --trace-out` / `profile --trace`
/// (the tier-1 smoke gate's pure-Rust replacement for jq). Exits
/// non-zero with a diagnostic on malformed traces.
fn run_trace_check(rest: &[String]) -> Result<()> {
    let Some(path) = rest.first() else {
        bail!("usage: lazydit trace-check <trace.json>");
    };
    let text = std::fs::read_to_string(path)?;
    let s = crate::obs::chrome::validate_chrome_trace(&text)?;
    println!(
        "trace-check: {path} OK — {} events ({} slices, {} instants) on \
         {} track(s)",
        s.events, s.slices, s.instants, s.tracks
    );
    Ok(())
}

fn print_help() {
    println!(
        "lazydit — LazyDiT serving framework (AAAI 2025 reproduction)\n\
         \n\
         workflow commands:\n\
         \x20 info          show manifest / artifact inventory\n\
         \x20 pretrain      train the base DiT on SynthBlobs-10 (AOT step)\n\
         \x20 lazy-train    train the lazy gates (paper Sec. 3.3)\n\
         \x20 generate      sample images; optional PNG grid output\n\
         \x20 serve         TCP JSON-lines serving with continuous batching\n\
         \x20 calibrate     profile a skip calendar for serve --calendar\n\
         \x20 eval          quality metrics for one sampling configuration\n\
         \n\
         paper experiment regenerators:\n\
         \x20 table1|table2|table5   quality vs DDIM across steps/lazy ratios\n\
         \x20 table3|table6          latency profiles (mobile-B1 / gpu-B8)\n\
         \x20 table7                 vs the Learn2Cache-analog baseline\n\
         \x20 fig4                   layer-wise laziness distribution\n\
         \x20 fig5                   penalty/laziness ablations\n\
         \x20 fig6                   skip-one-module-only ablation\n\
         \x20 profile                engine hot-path micro profile\n\
         \x20 trace-check            validate a --trace-out Chrome trace\n\
         \n\
         run `lazydit <cmd> --help` semantics: all options have defaults;\n\
         common ones: --artifacts <dir> --ckpt <dir> --config <name>."
    );
}
