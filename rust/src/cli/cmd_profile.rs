//! `lazydit profile` — engine hot-path micro profile: times each stage of
//! one denoise step (embed / modgate / module / apply / final / host) to
//! direct the L3 optimization pass (DESIGN.md §9).
//!
//! `--trace out.json` additionally records the end-to-end phase through
//! the telemetry ring (per-module run/skip spans with gate values) and
//! writes a Chrome-trace-format file — open it in Perfetto to see where
//! a denoise step's time actually goes (docs/OBSERVABILITY.md).

use crate::bench::harness::{bench, BenchSpec};
use crate::cli::common::{merge_specs, serve_config, EvalContext};
use crate::config::LazyScope;
use crate::coordinator::engine::{generate_batch, EngineOptions};
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "steps", help: "sampling steps", default: Some("20"), is_flag: false },
        OptSpec { name: "lazy", help: "lazy ratio % (0 = DDIM)", default: Some("0"), is_flag: false },
        OptSpec { name: "count", help: "images per iteration", default: Some("4"), is_flag: false },
        OptSpec { name: "iters", help: "bench iterations", default: Some("5"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes", default: Some("8"), is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance", default: Some("1.5"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "lazy scope", default: Some("both"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "queue-cap", help: "queue bound", default: Some("256"), is_flag: false },
        OptSpec { name: "trace", help: "write a Chrome-trace JSON of the e2e phase here", default: None, is_flag: false },
        OptSpec { name: "trace-ring", help: "trace ring capacity (events)", default: Some("65536"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate train steps if needed", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate train lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
    ])
}

pub fn run(a: Args) -> Result<()> {
    let ctx = EvalContext::open(&a, 32)?;
    let steps = a.get_usize("steps", 20)?;
    let lazy_pct = a.get_usize("lazy", 0)?;
    let count = a.get_usize("count", 4)?;
    let iters = a.get_usize("iters", 5)?;
    let serve = serve_config(&a, &ctx.cfg.model.name)?;

    let gamma = if lazy_pct > 0 {
        Some(ctx.ensure_gates(&a, steps, lazy_pct, LazyScope::Both)?)
    } else {
        None
    };

    let spec = BenchSpec { warmup: 1, iters };
    let labels: Vec<usize> = (0..count).map(|i| i % 10).collect();

    // end-to-end per-image latency
    let mut engine = match &gamma {
        Some(g) => ctx.engine(serve.clone(), EngineOptions::default(), Some(g))?,
        None => ctx.engine(serve.clone(),
                           EngineOptions { disable_gates: true, ..Default::default() },
                           None)?,
    };
    let trace_out = a.get("trace");
    let tracer = match &trace_out {
        Some(_) => crate::obs::Tracer::enabled(
            0, a.get_usize("trace-ring", 65536)?.max(2)),
        None => crate::obs::Tracer::disabled(),
    };
    if tracer.is_enabled() {
        crate::coordinator::pool::PoolEngine::install_tracer(
            &mut engine, tracer.clone());
    }
    let cfg_scale = engine.serve.cfg_scale;
    let mut seed = 0u64;
    let r = bench(
        &format!("e2e generate {count} img @ {steps} steps (lazy {lazy_pct}%)"),
        spec,
        || {
            seed += 1;
            generate_batch(&mut engine, &labels, steps, seed, cfg_scale)
                .expect("generate");
        },
    );
    println!("{}", r.summary());
    let per_img = r.mean_s / count as f64;
    let per_step = per_img / steps as f64;
    println!("  per image: {per_img:.4}s   per denoise step (CFG incl.): \
              {per_step:.5}s");
    println!("  engine lazy ratio: {:.1}%",
             100.0 * engine.layer_stats.row_overall_ratio());
    if let Some(path) = &trace_out {
        let groups =
            crate::obs::chrome::collect_tracers(&[tracer.clone()],
                                                usize::MAX);
        let summary = crate::obs::chrome::write_chrome_trace(
            std::path::Path::new(path), &groups)?;
        println!("  trace: {} events ({} slices) -> {path}",
                 summary.events, summary.slices);
    }

    // executable-level breakdown via direct runner calls
    let m = &ctx.cfg.model;
    let b = ctx.cfg.bucket_for(2).unwrap_or(1);
    let runner = &mut engine.runner;
    runner.warmup(b)?;
    let z = crate::tensor::Tensor::zeros(&[b, m.channels, m.img_size, m.img_size]);
    let t = vec![500.0f32; b];
    let y = vec![0i32; b];
    let live = vec![true; b];
    let pairs = vec![false; b];
    let dec = crate::model::runner::DecisionCfg {
        policy: crate::config::SkipPolicy::Never,
        scope: crate::config::LazyScope::Both,
        threshold: 0.5,
        row_granular: true,
    };
    let mut caches = crate::model::runner::BatchCaches::empty(
        m.depth, b, m.tokens(), m.dim);
    let r2 = bench("one full denoise step (no skips)", spec, || {
        runner
            .step(b, &z, &t, &y, &live, &pairs, &mut caches, dec)
            .expect("step");
    });
    println!("{}", r2.summary());
    let dec_all_skip = crate::model::runner::DecisionCfg {
        policy: crate::config::SkipPolicy::Any,
        scope: crate::config::LazyScope::Both,
        threshold: -1.0, // s > -1 always true ⇒ skip everything possible
        row_granular: true,
    };
    let r3 = bench("one full denoise step (all modules skipped)", spec, || {
        runner
            .step(b, &z, &t, &y, &live, &pairs, &mut caches, dec_all_skip)
            .expect("step");
    });
    println!("{}", r3.summary());
    println!(
        "  module-body share of a step: {:.1}%  (skip-all speedup {:.2}x)",
        100.0 * (1.0 - r3.mean_s / r2.mean_s),
        r2.mean_s / r3.mean_s
    );

    // §Perf before/after: per-call host→literal weight conversion (the
    // pre-optimization hot path) vs pre-built weight literals (call_lit).
    let spec_fast = BenchSpec { warmup: 5, iters: 200 };
    let exe = ctx.rt.load(&ctx.cfg, &format!("ffn_b{b}"))?;
    let host_args: Vec<crate::runtime::value::HostValue> = {
        let w = &runner.weights;
        let mut v = vec![crate::runtime::value::HostValue::F32(
            crate::tensor::Tensor::zeros(&[b, m.tokens(), m.dim]))];
        v.extend(w.ffn[0].iter().cloned());
        v
    };
    let r_before = bench("ffn call (convert weights per call) [BEFORE]",
                         spec_fast, || {
        exe.call(&host_args).expect("call");
    });
    let lit_args: Vec<xla::Literal> = host_args
        .iter()
        .map(|h| h.to_literal().unwrap())
        .collect();
    let refs: Vec<&xla::Literal> = lit_args.iter().collect();
    let r_after = bench("ffn call_lit (weights pre-converted) [AFTER]",
                        spec_fast, || {
        exe.call_lit(&refs).expect("call_lit");
    });
    println!("{}", r_before.summary());
    println!("{}", r_after.summary());
    println!("  per-call conversion overhead removed: {:.1}%  ({:.2}x)",
             100.0 * (1.0 - r_after.mean_s / r_before.mean_s),
             r_before.mean_s / r_after.mean_s);
    Ok(())
}
