//! `lazydit calibrate` — profile skip calendars offline.
//!
//! Runs a deterministic request trace through an engine — the real
//! model, or the simulator under `--synthetic` — and aggregates the
//! per-step run/seen row counters ([`PoolEngine::step_profile`]) into a
//! [`SkipCalendar`]: per step count, the expected executed module rows
//! at every step index. The calendar is written as a versioned JSON
//! artifact that `lazydit serve --calendar FILE` loads to price every
//! request at admission (see docs/SERVING.md, "Deadlines & skip
//! calendars").
//!
//! The artifact is stamped with the FNV-1a fingerprint of the same
//! model-identity descriptor `serve` folds into its `RequestKey`s, so a
//! calendar can only arm a server running the configuration it was
//! profiled on — `serve --calendar` refuses a mismatch loudly instead
//! of silently pricing with the wrong model's profile.
//!
//! Determinism contract: the same trace produces a byte-identical
//! artifact. The trace is seeded (request i carries seed i), the
//! simulator's skip draws are pure functions of (step, slot), and the
//! encoder walks sorted maps — no wall-clock or iteration-order noise
//! can leak into the bytes. The tier-1 gate asserts this by calibrating
//! twice and comparing files.

use crate::cli::cmd_serve::{engine_desc, fnv64, synthetic_desc};
use crate::cli::common::{merge_specs, serve_config, EvalContext};
use crate::config::{LazyScope, SkipPolicy};
use crate::coordinator::engine::EngineOptions;
use crate::coordinator::pool::calendar::StepProfile;
use crate::coordinator::pool::sim::{SimEngine, SimSpec};
use crate::coordinator::pool::{PoolEngine, SkipCalendar};
use crate::coordinator::request::Request;
use crate::util::argparse::{Args, OptSpec};
use anyhow::{bail, Context, Result};

/// CLI options for `lazydit calibrate`.
pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[
        OptSpec { name: "out", help: "calendar artifact path", default: Some("calendar.json"), is_flag: false },
        OptSpec { name: "request-steps", help: "step counts to profile, comma-separated", default: Some("4,20"), is_flag: false },
        OptSpec { name: "requests", help: "trace requests per step count", default: Some("32"), is_flag: false },
        OptSpec { name: "lazy", help: "lazy ratio % (0 = DDIM)", default: Some("50"), is_flag: false },
        OptSpec { name: "steps", help: "gate grid (training) steps", default: Some("20"), is_flag: false },
        OptSpec { name: "policy", help: "skip policy", default: Some("mean"), is_flag: false },
        OptSpec { name: "scope", help: "both|attn|ffn|none", default: Some("both"), is_flag: false },
        OptSpec { name: "max-batch", help: "max lanes per round", default: Some("8"), is_flag: false },
        OptSpec { name: "queue-cap", help: "admission bound", default: Some("256"), is_flag: false },
        OptSpec { name: "cfg-scale", help: "guidance scale", default: Some("1.5"), is_flag: false },
        OptSpec { name: "threshold", help: "gate threshold", default: Some("0.5"), is_flag: false },
        OptSpec { name: "coupled-gate", help: "legacy all-or-nothing batch skip gate", default: None, is_flag: true },
        OptSpec { name: "synthetic", help: "profile the synthetic engine (no artifacts needed)", default: None, is_flag: true },
        OptSpec { name: "sim-work", help: "synthetic spin per executed module", default: Some("4000"), is_flag: false },
        OptSpec { name: "train-steps", help: "gate training steps if needed", default: Some("200"), is_flag: false },
        OptSpec { name: "train-lr", help: "gate training lr", default: Some("5e-3"), is_flag: false },
        OptSpec { name: "pretrain-steps", help: "base steps if needed", default: Some("1500"), is_flag: false },
        OptSpec { name: "pretrain-lr", help: "base lr if needed", default: Some("2e-3"), is_flag: false },
    ])
}

/// Parse `--request-steps "4,20"` into a validated step-count list.
pub fn parse_request_steps(spec: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let s: usize = part
            .trim()
            .parse()
            .with_context(|| format!("bad step count '{}'", part.trim()))?;
        if s == 0 {
            bail!("--request-steps entries must be >= 1");
        }
        if !out.contains(&s) {
            out.push(s);
        }
    }
    if out.is_empty() {
        bail!("--request-steps parsed to zero step counts");
    }
    Ok(out)
}

/// Drive a deterministic `requests`-long trace of `steps`-step requests
/// through a fresh engine and return its per-step profile. Request `i`
/// carries seed `i` and cycles the class labels, matching the serve
/// smoke client, so calibrate and serve exercise the same decisions.
fn profile_trace(engine: &mut dyn PoolEngine, steps: usize, requests: u64,
                 cfg_scale: f32) -> Result<StepProfile> {
    for i in 0..requests {
        let mut req = Request::new(0, (i % 10) as usize, steps, i);
        req.cfg_scale = cfg_scale;
        engine.submit(req);
    }
    while engine.active_count() > 0 {
        engine.step_round()?;
    }
    engine
        .step_profile()
        .cloned()
        .context("this engine records no step profile — cannot calibrate")
}

pub fn run(a: Args) -> Result<()> {
    let out = a.get_str("out", "calendar.json");
    let requests = a.get_u64("requests", 32)?.max(1);
    let lazy_pct = a.get_usize("lazy", 50)?;
    let cfg_scale = a.get_f32("cfg-scale", 1.5)?;
    let step_list =
        parse_request_steps(&a.get_str("request-steps", "4,20"))?;

    // one fresh engine per step count: StepProfile is indexed by step
    // only, so mixing step counts on one engine would fold a 4-step
    // trace's tail into a 20-step trace's head
    let mut ctx_slot: Option<EvalContext> = None;
    let (desc, build): (String,
                        Box<dyn Fn() -> Result<Box<dyn PoolEngine>> + '_>) =
        if a.flag("synthetic") {
            let work = a.get_u64("sim-work", 4000)?;
            let coupled = a.flag("coupled-gate");
            let desc = synthetic_desc(lazy_pct, work, coupled);
            let spec = SimSpec {
                lazy_pct: lazy_pct as u32,
                work_per_module: work,
                coupled,
                ..SimSpec::default()
            };
            (desc, Box::new(move || {
                Ok(Box::new(SimEngine::new(spec.clone()))
                   as Box<dyn PoolEngine>)
            }))
        } else {
            ctx_slot = Some(EvalContext::open(&a, 32)?);
            let ctx = ctx_slot.as_ref().expect("context just opened");
            let mut serve_cfg = serve_config(&a, &ctx.cfg.model.name)?;
            let grid = a.get_usize("steps", 20)?;
            let gamma = if lazy_pct == 0 {
                serve_cfg.policy = SkipPolicy::Never;
                None
            } else {
                Some(ctx.ensure_gates(&a, grid, lazy_pct, LazyScope::Both)?)
            };
            let desc = engine_desc(&ctx.cfg.model.name,
                                   serve_cfg.policy.name(), lazy_pct, grid);
            (desc, Box::new(move || {
                let engine = ctx.engine(serve_cfg.clone(),
                                        EngineOptions::default(),
                                        gamma.as_deref())?;
                Ok(Box::new(engine) as Box<dyn PoolEngine>)
            }))
        };

    let fingerprint = fnv64(desc.as_bytes());
    let mut calendar: Option<SkipCalendar> = None;
    for &steps in &step_list {
        let mut engine = build()?;
        let profile = profile_trace(engine.as_mut(), steps, requests,
                                    cfg_scale)?;
        let cal = calendar.get_or_insert_with(|| {
            SkipCalendar::new(fingerprint, &engine.policy_name())
        });
        cal.insert_profile(steps, &profile, requests);
        let gamma = cal.implied_gamma(steps).unwrap_or(0.0);
        let cost = cal.cost_from(steps, 0).unwrap_or(0.0);
        println!("calibrate: steps={steps} requests={requests} \
                  cost={cost:.1} rows/request implied_gamma={gamma:.3}");
    }
    let cal = calendar.expect("step list is non-empty");
    std::fs::write(&out, cal.encode())
        .with_context(|| format!("writing calendar to {out}"))?;
    println!("calibrate: model={fingerprint:#018x} policy={} \
              step_counts={} -> {out}",
             cal.policy, cal.entries.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_steps_grammar_parses() {
        assert_eq!(parse_request_steps("4,20").unwrap(), vec![4, 20]);
        assert_eq!(parse_request_steps(" 8 , 8 ,2 ").unwrap(), vec![8, 2]);
        assert!(parse_request_steps("").is_err());
        assert!(parse_request_steps("0").is_err());
        assert!(parse_request_steps("x").is_err());
    }

    #[test]
    fn synthetic_trace_profiles_deterministically() {
        let spec = SimSpec { work_per_module: 10, ..SimSpec::default() };
        let mut a = SimEngine::new(spec.clone());
        let mut b = SimEngine::new(spec);
        let pa = profile_trace(&mut a, 4, 6, 1.0).unwrap();
        let pb = profile_trace(&mut b, 4, 6, 1.0).unwrap();
        assert_eq!(pa, pb, "same trace must profile identically");
        assert_eq!(pa.len(), 4);
        // step 0 never skips in the simulator (cold cache gate)
        assert_eq!(pa.run_rows(0), pa.seen_rows(0));
        let mut cal = SkipCalendar::new(0xabc, "sim");
        cal.insert_profile(4, &pa, 6);
        let re = SkipCalendar::decode(&cal.encode()).unwrap();
        assert_eq!(re, cal, "artifact must round-trip");
    }

    /// The calibrate-then-serve contract end to end: a calendar built
    /// from one profiled trace, pushed through the on-disk codec, must
    /// reproduce the laziness a *second identical* trace actually
    /// exhibits — both the implied Γ and the per-request priced cost.
    #[test]
    fn calibrated_calendar_reproduces_trace_gamma() {
        let steps = 6usize;
        let requests = 8u64;
        let spec = SimSpec { lazy_pct: 50, work_per_module: 10,
                             ..SimSpec::default() };

        // calibrate side: profile a trace, bake the calendar, round-trip
        // it through the artifact codec (what `serve --calendar` loads)
        let mut profiled = SimEngine::new(spec.clone());
        let profile = profile_trace(&mut profiled, steps, requests, 1.0)
            .unwrap();
        let mut cal = SkipCalendar::new(0xFEED, "sim");
        cal.insert_profile(steps, &profile, requests);
        let loaded = SkipCalendar::decode(&cal.encode()).unwrap();

        // serve side: replay the identical trace on a fresh engine and
        // measure the laziness it actually delivered
        let mut replay = SimEngine::new(spec);
        let observed = profile_trace(&mut replay, steps, requests, 1.0)
            .unwrap();
        let (run, seen) = (observed.total_run(), observed.total_seen());
        assert!(run < seen, "a 50%-lazy trace must skip something");
        // implied_gamma normalizes by the peak step; step 0 never skips
        // in the simulator, so the peak equals the uniform per-step seen
        // rows and the two Γ definitions coincide — check that premise
        // rather than silently rely on it
        for s in 0..steps {
            assert_eq!(observed.seen_rows(s), observed.run_rows(0),
                       "seen rows must be uniform for Γ comparability");
        }
        let trace_gamma = 1.0 - run as f64 / seen as f64;
        let implied = loaded.implied_gamma(steps)
            .expect("loaded calendar must imply a Γ for profiled steps");
        assert!((implied - trace_gamma).abs() < 1e-9,
                "loaded calendar Γ {implied} != trace Γ {trace_gamma}");

        // and the admission price for a full request equals the mean
        // executed module invocations the replay actually spent
        let cost = loaded.cost_from(steps, 0)
            .expect("loaded calendar must price profiled steps");
        let spent = run as f64 / requests as f64;
        assert!((cost - spent).abs() < 1e-9,
                "priced cost {cost} != replayed cost {spent}");
    }
}
