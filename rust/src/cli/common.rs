//! Shared CLI plumbing: engine/context construction, gate-checkpoint
//! naming, and ensure-trained helpers used by the table regenerators.

use crate::bench::quality::{FeatureExtractor, MetricContext};
use crate::config::{LazyScope, ServeConfig, SkipPolicy, TrainConfig};
use crate::coordinator::engine::{Engine, EngineOptions};
use crate::model::checkpoint::{gates_path, theta_path, Checkpoint};
use crate::runtime::engine_rt::Runtime;
use crate::runtime::manifest::{Manifest, ManifestConfig};
use crate::train::lazytrain::{lazy_train, LazyTrainOptions};
use crate::train::pretrain::pretrain;
use crate::util::argparse::{Args, OptSpec};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::rc::Rc;

pub const COMMON: &[OptSpec] = &[
    OptSpec { name: "artifacts", help: "artifacts dir", default: Some("artifacts"), is_flag: false },
    OptSpec { name: "ckpt", help: "checkpoint dir", default: Some("runs"), is_flag: false },
    OptSpec { name: "config", help: "model config", default: Some("xl-256a"), is_flag: false },
];

pub fn artifacts_dir(a: &Args) -> PathBuf {
    PathBuf::from(a.get_str("artifacts", "artifacts"))
}

pub fn ckpt_dir(a: &Args) -> PathBuf {
    PathBuf::from(a.get_str("ckpt", "runs"))
}

pub fn config_name(a: &Args) -> String {
    a.get_str("config", "xl-256a")
}

/// Gate-checkpoint tag for a (serve_steps, target-ratio) combination.
pub fn gate_tag(steps: usize, ratio_pct: usize, scope: LazyScope) -> String {
    let sc = match scope {
        LazyScope::Both => "",
        LazyScope::AttnOnly => "-attn",
        LazyScope::FfnOnly => "-ffn",
        LazyScope::None => "-none",
    };
    format!("s{steps}-r{ratio_pct}{sc}")
}

/// Loaded shared context for eval/table commands.
pub struct EvalContext {
    pub rt: Rc<Runtime>,
    pub cfg: ManifestConfig,
    pub theta: Vec<f32>,
    pub extractor: FeatureExtractor,
    pub metrics: MetricContext,
    pub artifacts: PathBuf,
    pub ckpt: PathBuf,
}

impl EvalContext {
    pub fn open(a: &Args, n_real: usize) -> Result<EvalContext> {
        let artifacts = artifacts_dir(a);
        let ckpt = ckpt_dir(a);
        let name = config_name(a);
        let manifest = Manifest::load(&artifacts)?;
        let cfg = manifest.config(&name)?.clone();
        let rt = Rc::new(Runtime::cpu()?);
        let theta = load_or_pretrain(&rt, &cfg, &ckpt, a)?;
        let extractor = FeatureExtractor::new(&rt, &cfg, manifest.feature_dim)?;
        let metrics = MetricContext::build(&extractor, cfg.model.img_size,
                                           n_real, 0xEEA1, threads())?;
        log::info!("metric context ready: {} real samples, IS-classifier \
                    accuracy {:.3}", n_real, metrics.clf_accuracy);
        Ok(EvalContext { rt, cfg, theta, extractor, metrics, artifacts, ckpt })
    }

    /// Build an engine sharing this context's θ.
    pub fn engine(&self, serve: ServeConfig, options: EngineOptions,
                  gamma: Option<&[f32]>) -> Result<Engine> {
        let runner = match gamma {
            Some(g) => crate::model::runner::ModelRunner::new(
                self.rt.clone(), self.cfg.clone(), &self.theta, g)?,
            None => crate::model::runner::ModelRunner::with_disabled_gates(
                self.rt.clone(), self.cfg.clone(), &self.theta)?,
        };
        Ok(Engine::from_parts(runner, serve, options))
    }

    /// Load gates for (steps, ratio), training them if absent.
    pub fn ensure_gates(&self, a: &Args, steps: usize, ratio_pct: usize,
                        scope: LazyScope) -> Result<Vec<f32>> {
        let tag = gate_tag(steps, ratio_pct, scope);
        let path = gates_path(&self.ckpt, &self.cfg.model.name, &tag);
        if let Ok(ck) = Checkpoint::load(&path) {
            return Ok(ck.vec("gamma")?.clone());
        }
        log::info!("gate checkpoint {tag} missing — training");
        let tc = TrainConfig {
            config_name: self.cfg.model.name.clone(),
            steps: a.get_usize("train-steps", 200)?,
            lr: a.get_f32("train-lr", 5e-3)?,
            ..Default::default()
        };
        let opts = LazyTrainOptions {
            serve_steps: steps,
            target_attn: Some(ratio_pct as f64 / 100.0),
            target_ffn: Some(ratio_pct as f64 / 100.0),
            scope,
            tag: tag.clone(),
            adjust_every: 10,
        };
        let report = lazy_train(&self.rt, &self.cfg, &tc, &opts, &self.theta,
                                &self.ckpt)?;
        log::info!("trained {tag}: frac a/f {:.2}/{:.2} ({:.1}s)",
                   report.final_frac_attn, report.final_frac_ffn,
                   report.wall_s);
        let ck = Checkpoint::load(&path)?;
        Ok(ck.vec("gamma")?.clone())
    }
}

/// Load θ, pretraining on the fly if the checkpoint is missing.
pub fn load_or_pretrain(rt: &Rc<Runtime>, cfg: &ManifestConfig, ckpt: &Path,
                        a: &Args) -> Result<Vec<f32>> {
    let path = theta_path(ckpt, &cfg.model.name);
    if let Ok(ck) = Checkpoint::load(&path) {
        return Ok(ck.vec("theta")?.clone());
    }
    log::info!("base checkpoint missing — pretraining {}", cfg.model.name);
    let tc = TrainConfig {
        config_name: cfg.model.name.clone(),
        steps: a.get_usize("pretrain-steps", 1500)?,
        lr: a.get_f32("pretrain-lr", 2e-3)?,
        ..Default::default()
    };
    let report = pretrain(rt, cfg, &tc, ckpt)?;
    log::info!("pretrained: loss {:.4} → {:.4} ({:.1}s)", report.first_loss,
               report.tail_loss, report.wall_s);
    let ck = Checkpoint::load(&path).context("checkpoint after pretrain")?;
    Ok(ck.vec("theta")?.clone())
}

/// Default serve config with CLI overrides applied.
pub fn serve_config(a: &Args, name: &str) -> Result<ServeConfig> {
    Ok(ServeConfig {
        config_name: name.to_string(),
        max_batch: a.get_usize("max-batch", 8)?,
        queue_cap: a.get_usize("queue-cap", 256)?,
        cfg_scale: a.get_f32("cfg-scale", 1.5)?,
        policy: SkipPolicy::parse(&a.get_str("policy", "mean"))?,
        scope: LazyScope::parse(&a.get_str("scope", "both"))?,
        threads: threads(),
        threshold: a.get_f32("threshold", 0.5)?,
        // row-granular skipping is the default; --coupled-gate (where a
        // command exposes it) restores the all-or-nothing batch gate
        row_granular: !a.flag("coupled-gate"),
        bucket_override: None,
    })
}

pub fn threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Merge common + command-specific specs (static tables in each command).
pub fn merge_specs(extra: &[OptSpec]) -> Vec<OptSpec> {
    COMMON.iter().cloned().chain(extra.iter().cloned()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags() {
        assert_eq!(gate_tag(50, 20, LazyScope::Both), "s50-r20");
        assert_eq!(gate_tag(20, 30, LazyScope::AttnOnly), "s20-r30-attn");
    }
}
