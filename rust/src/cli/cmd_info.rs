//! `lazydit info` — show the artifact inventory and parameter counts.

use crate::cli::common::{artifacts_dir, merge_specs};
use crate::runtime::manifest::Manifest;
use crate::util::argparse::{Args, OptSpec};
use anyhow::Result;

pub fn specs() -> Vec<OptSpec> {
    merge_specs(&[])
}

pub fn run(a: Args) -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir(&a))?;
    println!("artifacts: {}", manifest.root.display());
    println!("feature dim: {}", manifest.feature_dim);
    for (name, cfg) in &manifest.configs {
        let m = &cfg.model;
        println!(
            "\nconfig {name} (analog of {}):\n  img {s}x{s}x{c} patch {p} → {n} \
             tokens; D={d} L={l} heads={h}\n  θ: {tp} params  γ: {gp} gate params\
             \n  buckets {b:?}  train batch {tb}\n  graphs: {gc}",
            m.paper_analog,
            s = m.img_size,
            c = m.channels,
            p = m.patch,
            n = m.tokens(),
            d = m.dim,
            l = m.depth,
            h = m.heads,
            tp = cfg.theta_len(),
            gp = cfg.gamma_len(),
            b = cfg.buckets,
            tb = cfg.train_batch,
            gc = cfg.graphs.len(),
        );
        let macs = crate::tmacs::step_macs(m, true);
        println!(
            "  compute: {:.3} GMACs per denoise step (batch 1, gates on)",
            crate::tmacs::as_gmacs(macs)
        );
    }
    Ok(())
}
