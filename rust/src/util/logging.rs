//! Leveled stderr logger backing the `log` crate facade.
//!
//! Timestamps are relative to the shared telemetry epoch
//! ([`crate::obs::epoch`]), so log lines and trace-ring events
//! (docs/OBSERVABILITY.md) share one time base and can be correlated.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = crate::obs::epoch_us() as f64 / 1e6;
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl}] {}", record.args());
    }

    fn flush(&self) {}
}

/// Parse a `LAZYDIT_LOG` value; `None` means unrecognized.
fn parse_level(v: &str) -> Option<LevelFilter> {
    match v {
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger once; level from `LAZYDIT_LOG` (error|warn|info|debug|trace).
///
/// An unrecognized `LAZYDIT_LOG` value falls back to `info` and warns
/// once, instead of being silently swallowed.
pub fn init() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let raw = std::env::var("LAZYDIT_LOG").ok();
        let (level, bad) = match raw.as_deref() {
            None => (LevelFilter::Info, None),
            Some(v) => match parse_level(v) {
                Some(l) => (l, None),
                None => (LevelFilter::Info, Some(v.to_string())),
            },
        };
        if log::set_boxed_logger(Box::new(StderrLogger)).is_ok() {
            log::set_max_level(level);
            if let Some(v) = bad {
                log::warn!(
                    "unrecognized LAZYDIT_LOG={v:?} (want \
                     error|warn|info|debug|trace); defaulting to info"
                );
            }
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }

    #[test]
    fn level_parsing() {
        use log::LevelFilter;
        assert_eq!(super::parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(super::parse_level("trace"), Some(LevelFilter::Trace));
        assert_eq!(super::parse_level("verbose"), None);
        assert_eq!(super::parse_level(""), None);
    }
}
