//! CLI argument-parsing substrate (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! getters with defaults, required options, and auto-generated usage text.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Declarative option spec used for usage text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse raw argv (without program/subcommand names) against specs.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args> {
        let known_flags: Vec<&str> = specs
            .iter()
            .filter(|s| s.is_flag)
            .map(|s| s.name)
            .collect();
        let known_opts: Vec<&str> = specs
            .iter()
            .filter(|s| !s.is_flag)
            .map(|s| s.name)
            .collect();
        let mut out = Args {
            specs: specs.to_vec(),
            ..Default::default()
        };
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if known_flags.contains(&key.as_str()) {
                    if inline_val.is_some() {
                        bail!("flag --{key} does not take a value");
                    }
                    out.flags.push(key);
                } else if known_opts.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            if i >= argv.len() {
                                bail!("--{key} requires a value");
                            }
                            argv[i].clone()
                        }
                    };
                    out.opts.insert(key, val);
                } else {
                    bail!("unknown option --{key}");
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Programmatic override (used by table commands to force profile
    /// defaults like --max-batch for the mobile/GPU analogs).
    pub fn set(&mut self, name: &str, value: &str) {
        self.opts.insert(name.to_string(), value.to_string());
    }

    /// True if the user explicitly provided this option (not a default).
    pub fn provided(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    fn raw(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str()).or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .and_then(|s| s.default)
        })
    }

    pub fn get(&self, name: &str) -> Option<String> {
        self.raw(name).map(|s| s.to_string())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.raw(name).unwrap_or(default).to_string()
    }

    pub fn require(&self, name: &str) -> Result<String> {
        match self.raw(name) {
            Some(v) => Ok(v.to_string()),
            None => bail!("missing required option --{name}"),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.raw(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.raw(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.raw(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.raw(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Comma-separated list of usize, e.g. `--steps 50,25,10`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.raw(name) {
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<usize>().map_err(Into::into))
                .collect(),
            None => Ok(default.to_vec()),
        }
    }

    /// Comma-separated list of f64.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.raw(name) {
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<f64>().map_err(Into::into))
                .collect(),
            None => Ok(default.to_vec()),
        }
    }
}

/// Render aligned usage text for a spec table.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nusage: lazydit {cmd} [options]\n\noptions:\n");
    for spec in specs {
        let val = if spec.is_flag { "" } else { " <v>" };
        let dft = spec
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{:<14} {}{}\n", spec.name, val, spec.help, dft));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "config", help: "model", default: Some("nano"), is_flag: false },
            OptSpec { name: "steps", help: "steps", default: None, is_flag: false },
            OptSpec { name: "verbose", help: "more", default: None, is_flag: true },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_flag() {
        let a = Args::parse(&sv(&["--config", "xl-256a", "--verbose"]), &specs()).unwrap();
        assert_eq!(a.get_str("config", ""), "xl-256a");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&sv(&["--steps=25"]), &specs()).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 25);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get_str("config", "x"), "nano");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(&sv(&["--bogus", "1"]), &specs()).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(Args::parse(&sv(&["--steps"]), &specs()).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = Args::parse(&sv(&["out.png", "--config", "nano"]), &specs()).unwrap();
        assert_eq!(a.positional, vec!["out.png"]);
    }

    #[test]
    fn lists() {
        let a = Args::parse(&sv(&["--steps", "50,25,10"]), &specs()).unwrap();
        assert_eq!(a.get_usize_list("steps", &[]).unwrap(), vec![50, 25, 10]);
    }

    #[test]
    fn require_errors_without_value() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        assert!(a.require("steps").is_err());
        assert!(a.require("config").is_ok()); // has default
    }
}
