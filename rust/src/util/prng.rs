//! Deterministic PRNG substrate (the `rand` crate is not in the offline
//! vendor set): SplitMix64 seeding + xoshiro256++ core, with uniform /
//! normal / categorical helpers. All sampling in the system (dataset,
//! workloads, noise, initial latents) flows through this so runs are
//! reproducible from a single seed.

/// xoshiro256++ generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per-request) from this seed.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // top 24 bits -> [0,1) with full f32 mantissa coverage
        (self.next_u64() >> 40) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (small-n) uses
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn normal(&mut self) -> f32 {
        // no cache to stay Clone-simple; two uniforms per call is fine here
        let u1 = (self.uniform()).max(1e-9);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Sample an exponential inter-arrival time with the given rate (>0).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = (self.uniform() as f64).max(1e-12);
        -u.ln() / rate
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0f64;
        let n = 100_000;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
