//! Thread-pool + bounded-channel substrate (tokio is not in the offline
//! vendor set). The serving coordinator is thread-based: PJRT `execute`
//! calls are blocking, so an async runtime would only add overhead.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Outcome of a bounded wait on [`BoundedQueue::pop_timeout`].
#[derive(Debug, PartialEq)]
pub enum Popped<T> {
    /// An item arrived (or was already queued).
    Item(T),
    /// The queue is closed AND drained — no item will ever arrive.
    Closed,
    /// The deadline passed with the queue open but empty; callers that
    /// have other work sources (e.g. work stealing) re-check and retry.
    TimedOut,
}

/// A bounded MPMC channel with blocking send/recv — the backpressure
/// primitive used by admission control (DESIGN.md §7). Doubles as a
/// two-ended stealable queue: the owner consumes FIFO from the front
/// (`pop`/`try_pop`), thieves take LIFO from the back (`steal_back`),
/// so stolen work is the most recently enqueued — the jobs least likely
/// to be picked up by the owner next.
pub struct BoundedQueue<T> {
    inner: Arc<QueueInner<T>>,
}

struct QueueInner<T> {
    q: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for BoundedQueue<T> {
    fn clone(&self) -> Self {
        BoundedQueue { inner: self.inner.clone() }
    }
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        BoundedQueue {
            inner: Arc::new(QueueInner {
                q: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocking push; returns Err(item) if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.inner.cap {
                st.items.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking push; Err(item) if full or closed (admission shedding).
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed || st.items.len() >= self.inner.cap {
            return Err(item);
        }
        st.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; None once closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Non-blocking pop of the item that minimizes `key` — the EDF
    /// (earliest-deadline-first) sibling of [`try_pop`](Self::try_pop).
    /// Ties resolve to the *oldest* queued item (`min_by_key` keeps the
    /// first minimum it sees, and the scan runs front-to-back), so a
    /// queue of equal keys degrades to exact FIFO and same-deadline
    /// jobs can never starve each other. O(n) in queue length, which is
    /// bounded by the queue cap — the consumer holds the lock either
    /// way.
    pub fn try_pop_min_by_key<K, F>(&self, mut key: F) -> Option<T>
    where
        K: Ord,
        F: FnMut(&T) -> K,
    {
        let mut st = self.inner.q.lock().unwrap();
        let idx = st
            .items
            .iter()
            .enumerate()
            .min_by_key(|(_, it)| key(it))
            .map(|(i, _)| i)?;
        let item = st.items.remove(idx);
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Blocking pop with a deadline. Unlike [`pop`](Self::pop), an empty
    /// open queue eventually returns [`Popped::TimedOut`] so the caller
    /// can interleave other work sources (the replica worker's steal
    /// probe) with waiting on its own queue.
    pub fn pop_timeout(&self, timeout: Duration) -> Popped<T> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Popped::Item(item);
            }
            if st.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::TimedOut;
            }
            let (guard, _) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    /// [`pop_timeout`](Self::pop_timeout) with
    /// [`try_pop_min_by_key`](Self::try_pop_min_by_key)'s selection
    /// rule: waits like `pop_timeout`, but whenever items are present it
    /// takes the minimum-`key` one (first minimum wins, so equal keys
    /// are FIFO). The replica worker's idle wait uses this so a job
    /// with an earlier deadline that was queued *behind* a later one is
    /// still dispatched first.
    pub fn pop_timeout_min_by_key<K, F>(&self, timeout: Duration,
                                        mut key: F) -> Popped<T>
    where
        K: Ord,
        F: FnMut(&T) -> K,
    {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.q.lock().unwrap();
        loop {
            let idx = st
                .items
                .iter()
                .enumerate()
                .min_by_key(|(_, it)| key(it))
                .map(|(i, _)| i);
            if let Some(i) = idx {
                let item = st.items.remove(i);
                if item.is_some() {
                    self.inner.not_full.notify_one();
                }
                if let Some(item) = item {
                    return Popped::Item(item);
                }
            }
            if st.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::TimedOut;
            }
            let (guard, _) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    /// Non-blocking steal from the back — the thief side of the deque.
    /// Deliberately still works on a closed-but-undrained queue: during
    /// shutdown an idle replica stealing leftover jobs from an overloaded
    /// sibling *accelerates* the drain, it never violates it (every job
    /// still completes exactly once, just on the thief).
    pub fn steal_back(&self) -> Option<T> {
        self.steal_back_matching(|_| true)
    }

    /// Like [`steal_back`](Self::steal_back), but only takes a job the
    /// thief is allowed to run: scanning from the back (newest first),
    /// removes and returns the first item for which `eligible` is true.
    /// Items the predicate rejects stay exactly where they were, so the
    /// owner's FIFO order is preserved. Used by SLO-constrained work
    /// stealing — a thief must skip over jobs whose SLO class its own
    /// tier cannot honor rather than pop-and-re-push them (which would
    /// reorder the victim's queue and race its owner).
    pub fn steal_back_matching<F>(&self, mut eligible: F) -> Option<T>
    where
        F: FnMut(&T) -> bool,
    {
        let mut st = self.inner.q.lock().unwrap();
        let idx = st.items.iter().rposition(|it| eligible(it))?;
        let item = st.items.remove(idx);
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// True once `close` has been called (items may still be queued).
    pub fn is_closed(&self) -> bool {
        self.inner.q.lock().unwrap().closed
    }

    /// Drain up to `max` items without blocking (batcher pickup).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut st = self.inner.q.lock().unwrap();
        let n = st.items.len().min(max);
        let out: Vec<T> = st.items.drain(..n).collect();
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

/// Fixed worker pool executing boxed jobs.
pub struct ThreadPool {
    queue: BoundedQueue<Job>,
    workers: Vec<JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        let queue: BoundedQueue<Job> = BoundedQueue::new(queue_cap.max(1));
        let workers = (0..threads.max(1))
            .map(|i| {
                let q = queue.clone();
                std::thread::Builder::new()
                    .name(format!("lazydit-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = q.pop() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        // if closed, the job is dropped — shutdown path
        let _ = self.queue.push(Box::new(f));
    }

    /// Close the queue and join all workers (drains pending jobs first).
    pub fn shutdown(self) {
        self.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Scatter a workload over a transient pool and gather results in order.
/// Used by the metrics (k-NN) and data generators for CPU parallelism.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let results_mx = Mutex::new(&mut results);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|| loop {
                let next = work.lock().unwrap().pop_front();
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        (*results_mx.lock().unwrap())[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn queue_fifo() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_sheds_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert!(q.try_push(3).is_err());
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert!(q.push(8).is_err());
    }

    #[test]
    fn drain_up_to_takes_at_most() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        let got = q.drain_up_to(3);
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn steal_back_takes_newest_owner_pops_oldest() {
        let q = BoundedQueue::new(8);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.steal_back(), Some(3), "thief steals from the back");
        assert_eq!(q.pop(), Some(0), "owner still pops FIFO from the front");
        assert_eq!(q.steal_back(), Some(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.steal_back(), None);
    }

    #[test]
    fn steal_back_matching_skips_ineligible_newest() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        // newest is 4, but only even items are eligible → 4 goes
        assert_eq!(q.steal_back_matching(|&v: &i32| v % 2 == 0), Some(4));
        // newest eligible is now 2 (3 is skipped over, left in place)
        assert_eq!(q.steal_back_matching(|&v: &i32| v % 2 == 0), Some(2));
        // nothing eligible → None, queue untouched
        assert_eq!(q.steal_back_matching(|&v: &i32| v > 100), None);
        assert_eq!(q.len(), 3);
        // owner FIFO order preserved across mid-queue removals
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn try_pop_min_by_key_picks_earliest_and_ties_fifo() {
        let q = BoundedQueue::new(8);
        // (deadline, id): earliest deadline wins regardless of arrival
        for it in [(30u64, 0u32), (10, 1), (20, 2), (10, 3)] {
            q.push(it).unwrap();
        }
        // two items share deadline 10; the older one (id 1) must win —
        // first-minimum tie-break is what keeps equal keys exact FIFO
        assert_eq!(q.try_pop_min_by_key(|it| it.0), Some((10, 1)));
        assert_eq!(q.try_pop_min_by_key(|it| it.0), Some((10, 3)));
        assert_eq!(q.try_pop_min_by_key(|it| it.0), Some((20, 2)));
        assert_eq!(q.try_pop_min_by_key(|it| it.0), Some((30, 0)));
        assert_eq!(q.try_pop_min_by_key(|it| it.0), None);
    }

    #[test]
    fn min_by_key_with_equal_keys_is_exactly_fifo() {
        // EDF over a deadline-free workload must be indistinguishable
        // from the legacy FIFO pop — this is the no-regression guarantee
        // for clients that never send deadlines
        let q = BoundedQueue::new(8);
        for i in 0..6 {
            q.push(i).unwrap();
        }
        for want in 0..6 {
            assert_eq!(q.try_pop_min_by_key(|_| 0u64), Some(want));
        }
    }

    #[test]
    fn pop_timeout_min_by_key_selects_waits_and_closes() {
        let q = BoundedQueue::new(8);
        q.push((50u64, 'a')).unwrap();
        q.push((5, 'b')).unwrap();
        match q.pop_timeout_min_by_key(Duration::from_millis(50), |it| it.0) {
            Popped::Item(it) => assert_eq!(it, (5, 'b')),
            other => panic!("{other:?}"),
        }
        // empty + open → TimedOut (the worker's steal-probe interleave)
        match q.pop_timeout_min_by_key(Duration::from_millis(5), |it| it.0) {
            Popped::TimedOut => {}
            Popped::Item((_, c)) => panic!("unexpected item {c}"),
            Popped::Closed => panic!("not closed yet"),
        }
        // drains remaining items after close, then reports Closed
        q.close();
        match q.pop_timeout_min_by_key(Duration::from_millis(5), |it| it.0) {
            Popped::Item(it) => assert_eq!(it, (50, 'a')),
            other => panic!("{other:?}"),
        }
        match q.pop_timeout_min_by_key(Duration::from_millis(5), |it| it.0) {
            Popped::Closed => {}
            Popped::Item((_, c)) => panic!("unexpected item {c}"),
            Popped::TimedOut => panic!("closed, must not time out"),
        }
    }

    #[test]
    fn min_pop_and_steal_back_interoperate() {
        // a thief taking from the back and an EDF owner taking the
        // earliest deadline never hand out the same job twice
        let q = BoundedQueue::new(8);
        for it in [(40u64, 0u32), (10, 1), (30, 2), (20, 3)] {
            q.push(it).unwrap();
        }
        assert_eq!(q.steal_back(), Some((20, 3)), "thief takes newest");
        assert_eq!(q.try_pop_min_by_key(|it| it.0), Some((10, 1)));
        assert_eq!(q.steal_back(), Some((30, 2)));
        assert_eq!(q.try_pop_min_by_key(|it| it.0), Some((40, 0)));
        assert!(q.is_empty());
    }

    #[test]
    fn steal_back_drains_closed_queue() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.steal_back(), Some(2), "close still drains via steal");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.steal_back(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_timeout_distinguishes_empty_from_closed() {
        let q: BoundedQueue<usize> = BoundedQueue::new(4);
        let t = std::time::Duration::from_millis(5);
        assert_eq!(q.pop_timeout(t), Popped::TimedOut);
        q.push(9).unwrap();
        assert_eq!(q.pop_timeout(t), Popped::Item(9));
        q.close();
        assert_eq!(q.pop_timeout(t), Popped::Closed);
    }

    #[test]
    fn pop_timeout_wakes_on_push() {
        let q: BoundedQueue<usize> = BoundedQueue::new(4);
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            q2.pop_timeout(std::time::Duration::from_secs(10))
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.push(5).unwrap();
        assert_eq!(h.join().unwrap(), Popped::Item(5));
    }

    #[test]
    fn concurrent_steal_and_pop_conserve_items() {
        // every item goes to exactly one side — the mutex serializes the
        // two ends, so nothing is lost or duplicated under contention
        let q: BoundedQueue<usize> = BoundedQueue::new(1024);
        for i in 0..600 {
            q.push(i).unwrap();
        }
        q.close();
        let q2 = q.clone();
        let thief = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = q2.steal_back() {
                got.push(v);
            }
            got
        });
        let mut owner_got = Vec::new();
        while let Some(v) = q.try_pop() {
            owner_got.push(v);
        }
        let mut all = thief.join().unwrap();
        all.extend(owner_got);
        all.sort_unstable();
        assert_eq!(all, (0..600).collect::<Vec<_>>());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        let pool = ThreadPool::new(4, 64);
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v: Vec<usize> = (0..64).collect();
        let out = parallel_map(v, 8, |x| x * 2);
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn blocking_producer_consumer() {
        let q: BoundedQueue<usize> = BoundedQueue::new(2);
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut sum = 0;
            for _ in 0..50 {
                sum += q2.pop().unwrap();
            }
            sum
        });
        for i in 0..50 {
            q.push(i).unwrap(); // blocks when full — exercises backpressure
        }
        assert_eq!(h.join().unwrap(), (0..50).sum::<usize>());
    }
}
