//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Seeded generators + N-case loops + linear input shrinking.
//!
//! Usage:
//! ```ignore
//! propcheck(200, |g| {
//!     let n = g.usize_in(1, 64);
//!     let xs = g.vec_f32(n, -1.0, 1.0);
//!     prop_assert(sorted(&sort(xs.clone())) , "sort output sorted");
//! });
//! ```

use crate::util::prng::Rng;

/// Per-case generator handle with convenience samplers.
pub struct Gen {
    rng: Rng,
    /// Records scalar choices for failure reporting.
    pub trace: Vec<(String, String)>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    fn record(&mut self, label: &str, value: impl std::fmt::Debug) {
        if self.trace.len() < 64 {
            self.trace.push((label.to_string(), format!("{value:?}")));
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = lo + self.rng.below(hi - lo + 1);
        self.record("usize", v);
        v
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.record("u64", v);
        v
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = self.rng.uniform_in(lo, hi);
        self.record("f32", v);
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.record("bool", v);
        v
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.uniform_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v);
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len());
        self.record("choose_idx", i);
        &xs[i]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of a property. Panics (with seed and the
/// generator trace) on the first failing case so `cargo test` reports it.
/// Re-run a failure deterministically via `propcheck_seeded`.
pub fn propcheck<F: FnMut(&mut Gen)>(cases: u64, mut prop: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed (case {case}, seed {seed}): {msg}\n  inputs: {:?}\n  \
                 reproduce with propcheck_seeded({seed}, ..)",
                g.trace
            );
        }
    }
}

/// Deterministic single-case re-run for debugging a reported seed.
pub fn propcheck_seeded<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

fn base_seed() -> u64 {
    // allow override for reproducing CI failures
    match std::env::var("PROPCHECK_SEED") {
        Ok(s) => s.parse().unwrap_or(0xC0FFEE),
        Err(_) => 0xC0FFEE,
    }
}

/// Assert helper that formats like a property failure.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            panic!("prop_assert failed: {}", format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        propcheck(50, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert!(a + b >= a);
        });
    }

    #[test]
    fn reports_failures() {
        let r = std::panic::catch_unwind(|| {
            propcheck(50, |g| {
                let a = g.usize_in(0, 100);
                assert!(a < 90, "a was {a}");
            });
        });
        assert!(r.is_err(), "failing property must panic");
    }

    #[test]
    fn seeded_rerun_is_deterministic() {
        let mut first = None;
        propcheck_seeded(42, |g| {
            first = Some(g.u64());
        });
        let mut second = None;
        propcheck_seeded(42, |g| {
            second = Some(g.u64());
        });
        assert_eq!(first, second);
    }

    #[test]
    fn generators_in_range() {
        propcheck(100, |g| {
            let n = g.usize_in(3, 7);
            assert!((3..=7).contains(&n));
            let f = g.f32_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
            let v = g.vec_f32(n, 0.0, 1.0);
            assert_eq!(v.len(), n);
        });
    }
}
