//! Substrate utilities built from scratch (the offline vendor set has no
//! serde_json / rand / clap / tokio / criterion / proptest — see DESIGN.md §5).

pub mod json;
pub mod prng;
pub mod npy;
pub mod argparse;
pub mod threadpool;
pub mod propcheck;
pub mod logging;
