//! Minimal JSON parser/serializer substrate (serde_json is not available
//! in the offline vendor set).
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings
//! with escapes (incl. `\uXXXX` + surrogate pairs), numbers, booleans,
//! null. Numbers are stored as f64; the manifest never needs integers
//! beyond 2^53 so this is lossless for our use.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -------- typed accessors (ergonomic for manifest traversal) --------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Strict integer accessor: `Some` only for non-negative whole numbers
    /// strictly below 2^53. Values are stored as f64, so 2^53 itself is
    /// ambiguous (2^53 + 1 rounds to it) and larger magnitudes are not
    /// exactly representable — all such values are rejected rather than
    /// silently mangled (the wire protocol uses this for seeds).
    pub fn as_u64(&self) -> Option<u64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n < 0.0 || n >= 9_007_199_254_740_992.0 {
            return None;
        }
        Some(n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape-like arrays ([3, 16, 32]) as Vec<usize>.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // -------- constructors for serialization --------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

/// Parse/shape error with a short description.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let u1 = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&u1) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let u2 = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&u2) {
                                        return Err(self.err("bad low surrogate"));
                                    }
                                    0x10000 + ((u1 - 0xD800) << 10) + (u2 - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                u1
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // collect the raw utf-8 byte (strings arrive validated
                    // because the input is &str)
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len()
                        && self.b[end] & 0xC0 == 0x80
                    {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end]).map_err(
                        |_| self.err("bad utf8"),
                    )?);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------- ser

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e-4").unwrap(), Json::Num(1e-4));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "\"abc", "1.2.3", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"configs":{"nano":{"dim":32,"buckets":[1,2,4],"ok":true,"f":0.125}}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn strict_u64_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        // largest unambiguous integer (2^53 - 1)
        assert_eq!(
            Json::parse("9007199254740991").unwrap().as_u64(),
            Some(9_007_199_254_740_991)
        );
        // 2^53 is rejected: 2^53 + 1 rounds to the same f64, so accepting
        // it would silently alias two different wire values
        assert_eq!(Json::parse("9007199254740992").unwrap().as_u64(), None);
        assert_eq!(Json::parse("9007199254740993").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1e300").unwrap().as_u64(), None);
        assert_eq!(Json::parse("\"7\"").unwrap().as_u64(), None);
    }

    #[test]
    fn shape_accessor() {
        let v = Json::parse("[2, 16, 32]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 16, 32]);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }
}
