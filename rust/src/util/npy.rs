//! NumPy `.npy` reader/writer substrate — the interop format for golden
//! files dumped by `python/compile/aot.py` (DESIGN.md §8).
//!
//! Supports v1.0 headers with dtypes `<f4`, `<i4`, `<u4`, `<f8` in C order,
//! which covers everything the exporter produces.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// An array loaded from / destined for a .npy file.
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: NpyData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum NpyData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl NpyArray {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32, converting if needed.
    pub fn to_f32(&self) -> Vec<f32> {
        match &self.data {
            NpyData::F32(v) => v.clone(),
            NpyData::F64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::U32(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            NpyData::I32(v) => Ok(v),
            _ => bail!("npy: expected i32 data"),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match &self.data {
            NpyData::U32(v) => Ok(v),
            _ => bail!("npy: expected u32 data"),
        }
    }
}

const MAGIC: &[u8] = b"\x93NUMPY";

pub fn read(path: &Path) -> Result<NpyArray> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&bytes).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        bail!("not a .npy file");
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => (
            u16::from_le_bytes([bytes[8], bytes[9]]) as usize,
            10usize,
        ),
        2 | 3 => (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12usize,
        ),
        v => bail!("unsupported npy version {v}"),
    };
    let header = std::str::from_utf8(&bytes[header_start..header_start + header_len])?;
    let descr = dict_value(header, "descr").context("descr")?;
    let fortran = dict_value(header, "fortran_order").context("fortran")?;
    if fortran.trim() != "False" {
        bail!("fortran order not supported");
    }
    let shape_str = dict_value(header, "shape").context("shape")?;
    let shape: Vec<usize> = shape_str
        .trim()
        .trim_start_matches('(')
        .trim_end_matches(')')
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<usize>().context("shape int"))
        .collect::<Result<_>>()?;
    let n: usize = shape.iter().product();
    let body = &bytes[header_start + header_len..];
    let descr = descr.trim().trim_matches('\'').trim_matches('"');
    let data = match descr {
        "<f4" => {
            ensure_len(body, n, 4)?;
            NpyData::F32(body.chunks_exact(4).take(n)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        }
        "<f8" => {
            ensure_len(body, n, 8)?;
            NpyData::F64(body.chunks_exact(8).take(n)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
        }
        "<i4" => {
            ensure_len(body, n, 4)?;
            NpyData::I32(body.chunks_exact(4).take(n)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        }
        "<u4" => {
            ensure_len(body, n, 4)?;
            NpyData::U32(body.chunks_exact(4).take(n)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        }
        other => bail!("unsupported dtype {other}"),
    };
    Ok(NpyArray { shape, data })
}

fn ensure_len(body: &[u8], n: usize, w: usize) -> Result<()> {
    if body.len() < n * w {
        bail!("npy body too short: {} < {}", body.len(), n * w);
    }
    Ok(())
}

/// Tiny extractor for the python-dict-literal header: finds `'key': value`.
fn dict_value<'a>(header: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("'{key}':");
    let at = header.find(&pat).with_context(|| format!("key {key}"))?;
    let rest = &header[at + pat.len()..];
    // value ends at the next top-level comma or closing brace
    let mut depth = 0usize;
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth = depth.saturating_sub(1),
            ',' | '}' if depth == 0 => return Ok(rest[..i].trim()),
            _ => {}
        }
    }
    Ok(rest.trim())
}

pub fn write(path: &Path, shape: &[usize], data: &[f32]) -> Result<()> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape/data mismatch: {n} vs {}", data.len());
    }
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad to 64-byte alignment of magic+len+header+\n
    let unpadded = MAGIC.len() + 4 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read every byte of a stream (helper for tests).
pub fn read_all(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("lazydit_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.npy");
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        write(&p, &[2, 3, 4], &data).unwrap();
        let arr = read(&p).unwrap();
        assert_eq!(arr.shape, vec![2, 3, 4]);
        assert_eq!(arr.to_f32(), data);
    }

    #[test]
    fn roundtrip_scalar() {
        let dir = std::env::temp_dir().join("lazydit_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.npy");
        write(&p, &[], &[42.0]).unwrap();
        let arr = read(&p).unwrap();
        assert_eq!(arr.shape, Vec::<usize>::new());
        assert_eq!(arr.to_f32(), vec![42.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(b"not numpy at all").is_err());
    }

    #[test]
    fn header_dict_parser() {
        let h = "{'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }";
        assert_eq!(dict_value(h, "descr").unwrap(), "'<f4'");
        assert_eq!(dict_value(h, "shape").unwrap(), "(2, 3)");
        assert_eq!(dict_value(h, "fortran_order").unwrap(), "False");
    }
}
