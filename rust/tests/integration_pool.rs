//! Replica-pool integration: routing, drain, shedding, determinism, and
//! the aggregation invariant (pool-wide stats == sum of per-replica
//! stats). Runs entirely on the synthetic engine — no artifacts needed.

use lazydit::config::RoutePolicy;
use lazydit::coordinator::pool::replica::ReplicaHandle;
use lazydit::coordinator::pool::sim::{sim_image, SimEngine, SimSpec};
use lazydit::coordinator::pool::steal::Rebalancer;
use lazydit::coordinator::pool::Router;
use lazydit::coordinator::request::{Request, RequestResult};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};

fn build_router(specs: Vec<SimSpec>, route: RoutePolicy,
                queue_cap: usize) -> Router {
    let handles: Vec<ReplicaHandle> = specs
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            ReplicaHandle::spawn(i, queue_cap, SimEngine::factory(s)).unwrap()
        })
        .collect();
    Router::new(handles, route, queue_cap)
}

/// Pool with work stealing armed: a shared rebalancer with the given
/// in-engine admission window (jobs beyond it stay queued/migratable).
fn build_stealing_router(specs: Vec<SimSpec>, route: RoutePolicy,
                         queue_cap: usize, window: usize) -> Router {
    let rb = Rebalancer::new(window);
    let handles: Vec<ReplicaHandle> = specs
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            ReplicaHandle::spawn_with(i, queue_cap, SimEngine::factory(s),
                                      Some(rb.clone()))
            .unwrap()
        })
        .collect();
    Router::with_rebalancer(handles, route, queue_cap, Some(rb))
}

/// Dispatch a fixed workload closed-loop and gather every result.
fn run_workload(router: &Router, n: usize, steps: usize)
                -> (Vec<RequestResult>, usize) {
    let mut rxs = Vec::new();
    let mut shed = 0usize;
    for i in 0..n {
        let (tx, rx) = mpsc::channel();
        let req = Request::new(0, i % 10, steps, 1000 + i as u64);
        if router.dispatch(req, tx) {
            rxs.push(rx);
        } else {
            shed += 1;
        }
    }
    let mut out = Vec::new();
    for rx in rxs {
        out.push(rx.recv().expect("response"));
    }
    (out, shed)
}

#[test]
fn pool_aggregate_matches_sum_of_replicas() {
    // deliberately heterogeneous replicas (different Γ targets): the
    // pool-wide view must be the ratio of summed counters, not an
    // average of per-replica ratios
    let specs = vec![
        SimSpec { lazy_pct: 0, policy: "never".into(), ..SimSpec::fast() },
        SimSpec { lazy_pct: 50, policy: "mean".into(), ..SimSpec::fast() },
        SimSpec { lazy_pct: 80, policy: "aggressive".into(), ..SimSpec::fast() },
    ];
    let router = build_router(specs, RoutePolicy::RoundRobin, 1024);
    let (results, shed) = run_workload(&router, 30, 8);
    assert_eq!(results.len(), 30);
    assert_eq!(shed, 0);
    // wire ids are pool-unique even though each replica engine numbers
    // its own requests from 1
    let ids: std::collections::BTreeSet<u64> =
        results.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), 30, "response ids must not collide across replicas");

    let report = router.shutdown();
    assert_eq!(report.replicas.len(), 3);
    assert_eq!(report.failed(), 0);

    // ---- the aggregation invariant, counter by counter
    let merged = report.merged_layer();
    let serve = report.merged_serve();
    let mut sum_skips = 0u64;
    let mut sum_total = 0u64;
    let mut sum_completed = 0usize;
    let mut sum_inv = 0u64;
    let mut sum_skip_inv = 0u64;
    for r in &report.replicas {
        sum_skips += r.layer.skips.iter().sum::<u64>();
        sum_total += r.layer.total.iter().sum::<u64>();
        sum_completed += r.serve.completed;
        sum_inv += r.serve.module_invocations;
        sum_skip_inv += r.serve.module_skips;
    }
    assert_eq!(merged.skips.iter().sum::<u64>(), sum_skips);
    assert_eq!(merged.total.iter().sum::<u64>(), sum_total);
    assert_eq!(serve.completed, sum_completed);
    assert_eq!(serve.module_invocations, sum_inv);
    assert_eq!(serve.module_skips, sum_skip_inv);
    assert_eq!(sum_completed, 30);
    // Γ: ratio of sums
    let gamma = report.overall_lazy();
    assert!((gamma - sum_skips as f64 / sum_total as f64).abs() < 1e-12);
    // per-layer laziness sums slot-wise too
    for k in 0..merged.skips.len() {
        let s: u64 = report.replicas.iter().map(|r| r.layer.skips[k]).sum();
        assert_eq!(merged.skips[k], s, "slot {k}");
    }
    // shed count propagates into the merged serve stats
    assert_eq!(serve.shed, report.shed as usize);
    // every request ran its full trajectory: 30 requests × 8 steps ×
    // (2·depth) module slots
    let depth = SimSpec::fast().depth;
    assert_eq!(sum_total, (30 * 8 * 2 * depth) as u64);
}

#[test]
fn outputs_deterministic_across_replica_counts_and_routes() {
    // reference: what each (seed, label, steps) must produce
    let elems = SimSpec::fast().img_elems;
    let reference: BTreeMap<u64, Vec<f32>> = (0..24u64)
        .map(|i| {
            let req = Request::new(0, (i % 10) as usize, 6, 1000 + i);
            (1000 + i, sim_image(&req, elems).data().to_vec())
        })
        .collect();

    for (replicas, route) in [
        (1, RoutePolicy::RoundRobin),
        (3, RoutePolicy::Jsq),
        (4, RoutePolicy::Lazy),
    ] {
        let specs = vec![SimSpec::fast(); replicas];
        let router = build_router(specs, route, 1024);
        let (results, shed) = run_workload(&router, 24, 6);
        assert_eq!(shed, 0);
        assert_eq!(results.len(), 24);
        // every result's image must be byte-identical to the reference
        // for its seed, and all 24 seeds must be covered exactly once —
        // regardless of pool shape or routing policy
        let mut seen = std::collections::BTreeSet::new();
        for r in &results {
            let seed = seed_of(r, &reference);
            assert!(seen.insert(seed),
                    "duplicate image for seed {seed} (replicas={replicas}, \
                     route={})", route.name());
        }
        assert_eq!(seen.len(), 24);
        router.shutdown();
    }
}

/// Recover the workload seed whose reference image matches this result.
fn seed_of(r: &RequestResult, reference: &BTreeMap<u64, Vec<f32>>) -> u64 {
    for (seed, img) in reference {
        if img.as_slice() == r.image.data() {
            return *seed;
        }
    }
    panic!("result image matches no reference — determinism broken");
}

#[test]
fn admission_bound_sheds_and_counts() {
    // 1 replica, slow modules, pool-wide bound of 4 outstanding
    let specs = vec![SimSpec {
        work_per_module: 200_000,
        lazy_pct: 0,
        ..SimSpec::default()
    }];
    let router = build_router(specs, RoutePolicy::Jsq, 4);
    let mut rxs = Vec::new();
    let mut refused = 0usize;
    for i in 0..32 {
        let (tx, rx) = mpsc::channel();
        if router.dispatch(Request::new(0, 1, 4, i), tx) {
            rxs.push(rx);
        } else {
            refused += 1;
        }
    }
    assert!(refused > 0, "with bound 4 and 32 instant arrivals, some shed");
    assert_eq!(router.shed_count(), refused as u64);
    for rx in rxs {
        rx.recv().expect("admitted requests must complete");
    }
    let report = router.shutdown();
    assert_eq!(report.shed, refused as u64);
    assert_eq!(report.completed() + refused, 32);
}

#[test]
fn shutdown_drains_in_flight_trajectories() {
    let specs = vec![SimSpec::fast(); 2];
    let router = build_router(specs, RoutePolicy::RoundRobin, 64);
    let mut rxs = Vec::new();
    for i in 0..12 {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(Request::new(0, 2, 10, 500 + i), tx));
        rxs.push(rx);
    }
    // immediate shutdown: drain semantics must finish all 12
    let report = router.shutdown();
    assert_eq!(report.completed(), 12);
    for rx in rxs {
        assert!(rx.recv().is_ok(), "in-flight request lost at shutdown");
    }
}

#[test]
fn concurrent_dispatch_never_overruns_admission_cap() {
    // the shed ledger is check-then-act-free: N threads flooding
    // dispatch must never admit more than queue_cap outstanding
    // requests. The replica is slow enough that nothing completes
    // while the flood is in flight, so `admitted <= cap` is exact.
    let cap = 8usize;
    let specs = vec![SimSpec {
        work_per_module: 500_000,
        lazy_pct: 0,
        ..SimSpec::default()
    }];
    let router = Arc::new(build_router(specs, RoutePolicy::Jsq, cap));
    let threads = 8usize;
    let per = 8usize;
    let mut joins = Vec::new();
    for t in 0..threads {
        let r = router.clone();
        joins.push(std::thread::spawn(move || {
            let mut rxs = Vec::new();
            let mut shed = 0usize;
            for i in 0..per {
                let (tx, rx) = mpsc::channel();
                let req = Request::new(0, 1, 6, (t * per + i) as u64);
                if r.dispatch(req, tx) {
                    rxs.push(rx);
                } else {
                    shed += 1;
                }
            }
            (rxs, shed)
        }));
    }
    let mut rxs = Vec::new();
    let mut shed = 0usize;
    for j in joins {
        let (r, s) = j.join().unwrap();
        rxs.extend(r);
        shed += s;
    }
    // completions during the flood legitimately free admission slots
    // (resolved() grows), so bound by cap + whatever resolved by the
    // time the flood ended — on an unloaded machine that term is 0
    let completed_during_flood = router.total_completed() as usize;
    assert_eq!(rxs.len() + shed, threads * per);
    assert!(rxs.len() <= cap + completed_during_flood,
            "admission overrun: {} admitted with cap {cap} (+{} completed \
             mid-flood)", rxs.len(), completed_during_flood);
    assert!(shed > 0, "a 64-request flood against cap 8 must shed");
    assert_eq!(router.shed_count(), shed as u64);
    for rx in &rxs {
        rx.recv().expect("admitted requests must complete");
    }
    let report = router.shutdown();
    assert_eq!(report.completed(), rxs.len());
    assert_eq!(report.shed, shed as u64);
}

#[test]
fn stealing_migrates_without_losing_or_duplicating_jobs() {
    // skewed pool: replica 0 never skips (slow), replica 1 skips ~90%
    // (fast). With a window of 1 almost everything waits in queues, so
    // the fast replica drains its own share and then must steal the
    // slow replica's stranded jobs.
    let specs = vec![SimSpec::with_lazy(0, 100_000),
                     SimSpec::with_lazy(90, 100_000)];
    let router = build_stealing_router(specs, RoutePolicy::Jsq, 1024, 1);
    let (results, shed) = run_workload(&router, 32, 6);
    assert_eq!(shed, 0);
    assert_eq!(results.len(), 32, "every job answered exactly once");
    let ids: std::collections::BTreeSet<u64> =
        results.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), 32, "no duplicated responses after migration");
    // all responses received → every queued-gauge transfer unwound
    assert_eq!(router.total_queued(), 0,
               "gauges must drain to zero after migrations");
    let report = router.shutdown();
    assert_eq!(report.failed(), 0);
    assert_eq!(report.completed(), 32);
    assert!(report.total_steals() > 0,
            "fast replica must have stolen from the stranded slow one");
    assert_eq!(report.total_steals(), report.total_stolen(),
               "each migration has exactly one thief and one victim");
    // the thief is the lazy replica, the victim the never-skip one
    assert!(report.replicas[1].steals > 0);
    assert!(report.replicas[0].stolen > 0);
    assert!(report.render().contains("stole"),
            "steal counters surface in the pool report");
}

#[test]
fn stealing_preserves_drain_semantics_at_shutdown() {
    // close the pool immediately after flooding: drain + steal must
    // still complete every admitted job exactly once (thieves may pull
    // from closed-but-undrained sibling queues)
    let specs = vec![SimSpec::with_lazy(0, 50_000),
                     SimSpec::with_lazy(90, 50_000)];
    let router = build_stealing_router(specs, RoutePolicy::Jsq, 256, 1);
    let mut rxs = Vec::new();
    for i in 0..16 {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(Request::new(0, 2, 5, 900 + i), tx));
        rxs.push(rx);
    }
    let report = router.shutdown();
    assert_eq!(report.completed(), 16);
    for rx in rxs {
        assert!(rx.recv().is_ok(), "in-flight request lost at shutdown");
    }
    assert_eq!(report.total_steals(), report.total_stolen());
}

#[test]
fn stealing_outputs_stay_deterministic() {
    // migration must not change what any request produces — only where
    let elems = SimSpec::fast().img_elems;
    let reference: BTreeMap<u64, Vec<f32>> = (0..24u64)
        .map(|i| {
            let req = Request::new(0, (i % 10) as usize, 6, 1000 + i);
            (1000 + i, sim_image(&req, elems).data().to_vec())
        })
        .collect();
    let specs = vec![SimSpec::fast(); 3];
    let router = build_stealing_router(specs, RoutePolicy::Lazy, 1024, 2);
    let (results, shed) = run_workload(&router, 24, 6);
    assert_eq!(shed, 0);
    let mut seen = std::collections::BTreeSet::new();
    for r in &results {
        let seed = seed_of(r, &reference);
        assert!(seen.insert(seed), "duplicate image for seed {seed}");
    }
    assert_eq!(seen.len(), 24);
    router.shutdown();
}

#[test]
fn jsq_balances_across_replicas() {
    let specs = vec![SimSpec::fast(); 4];
    let router = build_router(specs, RoutePolicy::Jsq, 1024);
    let (results, _) = run_workload(&router, 40, 6);
    assert_eq!(results.len(), 40);
    let report = router.shutdown();
    // JSQ's tie-break walks the pool before reusing a replica, so with
    // 40 instant arrivals nobody can be starved outright
    for r in &report.replicas {
        assert!(r.serve.completed >= 1,
                "replica {} served nothing", r.id);
    }
    assert_eq!(report.completed(), 40);
}

#[test]
fn per_replica_policy_labels_surface_in_report() {
    let specs = vec![
        SimSpec { policy: "mean".into(), lazy_pct: 90, ..SimSpec::fast() },
        SimSpec { policy: "never".into(), lazy_pct: 0, ..SimSpec::fast() },
    ];
    let router = build_router(specs, RoutePolicy::RoundRobin, 64);
    let (results, _) = run_workload(&router, 8, 4);
    assert_eq!(results.len(), 8);
    let report = router.shutdown();
    let labels: Vec<&str> =
        report.replicas.iter().map(|r| r.policy.as_str()).collect();
    assert_eq!(labels, vec!["mean", "never"]);
    // the never replica must report Γ = 0 — the A/B contrast is real
    assert_eq!(report.replicas[1].layer.overall_ratio(), 0.0);
    assert!(report.replicas[0].layer.overall_ratio() > 0.0);
    let rendered = report.render();
    assert!(rendered.contains("mean") && rendered.contains("never"));
}
