//! Replica-pool integration: routing, drain, shedding, determinism,
//! SLO tiering, the `STATS` wire verb, and the aggregation invariant
//! (pool-wide stats == sum of per-replica stats). Runs entirely on the
//! synthetic engine — no artifacts needed.

use lazydit::config::{RoutePolicy, Slo};
use lazydit::coordinator::pool::replica::{ReplicaHandle, ReplicaTier};
use lazydit::coordinator::pool::sim::{sim_image, SimEngine, SimSpec};
use lazydit::coordinator::pool::steal::Rebalancer;
use lazydit::coordinator::pool::{PoolEngine, Router};
use lazydit::coordinator::request::{Request, RequestResult};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};

fn build_router(specs: Vec<SimSpec>, route: RoutePolicy,
                queue_cap: usize) -> Router {
    let handles: Vec<ReplicaHandle> = specs
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            ReplicaHandle::spawn(i, queue_cap, SimEngine::factory(s)).unwrap()
        })
        .collect();
    Router::new(handles, route, queue_cap)
}

/// Pool with work stealing armed: a shared rebalancer with the given
/// in-engine admission window (jobs beyond it stay queued/migratable).
fn build_stealing_router(specs: Vec<SimSpec>, route: RoutePolicy,
                         queue_cap: usize, window: usize) -> Router {
    let rb = Rebalancer::new(window);
    let handles: Vec<ReplicaHandle> = specs
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            ReplicaHandle::spawn_with(i, queue_cap, SimEngine::factory(s),
                                      Some(rb.clone()))
            .unwrap()
        })
        .collect();
    Router::with_rebalancer(handles, route, queue_cap, Some(rb))
}

/// Dispatch a fixed workload closed-loop and gather every result.
fn run_workload(router: &Router, n: usize, steps: usize)
                -> (Vec<RequestResult>, usize) {
    let mut rxs = Vec::new();
    let mut shed = 0usize;
    for i in 0..n {
        let (tx, rx) = mpsc::channel();
        let req = Request::new(0, i % 10, steps, 1000 + i as u64);
        if router.dispatch(req, tx) {
            rxs.push(rx);
        } else {
            shed += 1;
        }
    }
    let mut out = Vec::new();
    for rx in rxs {
        out.push(rx.recv().expect("response"));
    }
    (out, shed)
}

#[test]
fn pool_aggregate_matches_sum_of_replicas() {
    // deliberately heterogeneous replicas (different Γ targets): the
    // pool-wide view must be the ratio of summed counters, not an
    // average of per-replica ratios
    let specs = vec![
        SimSpec { lazy_pct: 0, policy: "never".into(), ..SimSpec::fast() },
        SimSpec { lazy_pct: 50, policy: "mean".into(), ..SimSpec::fast() },
        SimSpec { lazy_pct: 80, policy: "aggressive".into(), ..SimSpec::fast() },
    ];
    let router = build_router(specs, RoutePolicy::RoundRobin, 1024);
    let (results, shed) = run_workload(&router, 30, 8);
    assert_eq!(results.len(), 30);
    assert_eq!(shed, 0);
    // wire ids are pool-unique even though each replica engine numbers
    // its own requests from 1
    let ids: std::collections::BTreeSet<u64> =
        results.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), 30, "response ids must not collide across replicas");

    let report = router.shutdown();
    assert_eq!(report.replicas.len(), 3);
    assert_eq!(report.failed(), 0);

    // ---- the aggregation invariant, counter by counter
    let merged = report.merged_layer();
    let serve = report.merged_serve();
    let mut sum_skips = 0u64;
    let mut sum_total = 0u64;
    let mut sum_completed = 0usize;
    let mut sum_inv = 0u64;
    let mut sum_skip_inv = 0u64;
    for r in &report.replicas {
        sum_skips += r.layer.skips.iter().sum::<u64>();
        sum_total += r.layer.total.iter().sum::<u64>();
        sum_completed += r.serve.completed;
        sum_inv += r.serve.module_invocations;
        sum_skip_inv += r.serve.module_skips;
    }
    assert_eq!(merged.skips.iter().sum::<u64>(), sum_skips);
    assert_eq!(merged.total.iter().sum::<u64>(), sum_total);
    assert_eq!(serve.completed, sum_completed);
    assert_eq!(serve.module_invocations, sum_inv);
    assert_eq!(serve.module_skips, sum_skip_inv);
    assert_eq!(sum_completed, 30);
    // Γ: ratio of sums
    let gamma = report.overall_lazy();
    assert!((gamma - sum_skips as f64 / sum_total as f64).abs() < 1e-12);
    // per-layer laziness sums slot-wise too
    for k in 0..merged.skips.len() {
        let s: u64 = report.replicas.iter().map(|r| r.layer.skips[k]).sum();
        assert_eq!(merged.skips[k], s, "slot {k}");
    }
    // shed count propagates into the merged serve stats
    assert_eq!(serve.shed, report.shed as usize);
    // every request ran its full trajectory: 30 requests × 8 steps ×
    // (2·depth) module slots
    let depth = SimSpec::fast().depth;
    assert_eq!(sum_total, (30 * 8 * 2 * depth) as u64);
}

#[test]
fn outputs_deterministic_across_replica_counts_and_routes() {
    // reference: what each (seed, label, steps) must produce
    let elems = SimSpec::fast().img_elems;
    let reference: BTreeMap<u64, Vec<f32>> = (0..24u64)
        .map(|i| {
            let req = Request::new(0, (i % 10) as usize, 6, 1000 + i);
            (1000 + i, sim_image(&req, elems).data().to_vec())
        })
        .collect();

    for (replicas, route) in [
        (1, RoutePolicy::RoundRobin),
        (3, RoutePolicy::Jsq),
        (4, RoutePolicy::Lazy),
    ] {
        let specs = vec![SimSpec::fast(); replicas];
        let router = build_router(specs, route, 1024);
        let (results, shed) = run_workload(&router, 24, 6);
        assert_eq!(shed, 0);
        assert_eq!(results.len(), 24);
        // every result's image must be byte-identical to the reference
        // for its seed, and all 24 seeds must be covered exactly once —
        // regardless of pool shape or routing policy
        let mut seen = std::collections::BTreeSet::new();
        for r in &results {
            let seed = seed_of(r, &reference);
            assert!(seen.insert(seed),
                    "duplicate image for seed {seed} (replicas={replicas}, \
                     route={})", route.name());
        }
        assert_eq!(seen.len(), 24);
        router.shutdown();
    }
}

/// Recover the workload seed whose reference image matches this result.
fn seed_of(r: &RequestResult, reference: &BTreeMap<u64, Vec<f32>>) -> u64 {
    for (seed, img) in reference {
        if img.as_slice() == r.image.data() {
            return *seed;
        }
    }
    panic!("result image matches no reference — determinism broken");
}

#[test]
fn admission_bound_sheds_and_counts() {
    // 1 replica, slow modules, pool-wide bound of 4 outstanding
    let specs = vec![SimSpec {
        work_per_module: 200_000,
        lazy_pct: 0,
        ..SimSpec::default()
    }];
    let router = build_router(specs, RoutePolicy::Jsq, 4);
    let mut rxs = Vec::new();
    let mut refused = 0usize;
    for i in 0..32 {
        let (tx, rx) = mpsc::channel();
        if router.dispatch(Request::new(0, 1, 4, i), tx) {
            rxs.push(rx);
        } else {
            refused += 1;
        }
    }
    assert!(refused > 0, "with bound 4 and 32 instant arrivals, some shed");
    assert_eq!(router.shed_count(), refused as u64);
    for rx in rxs {
        rx.recv().expect("admitted requests must complete");
    }
    let report = router.shutdown();
    assert_eq!(report.shed, refused as u64);
    assert_eq!(report.completed() + refused, 32);
}

#[test]
fn shutdown_drains_in_flight_trajectories() {
    let specs = vec![SimSpec::fast(); 2];
    let router = build_router(specs, RoutePolicy::RoundRobin, 64);
    let mut rxs = Vec::new();
    for i in 0..12 {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(Request::new(0, 2, 10, 500 + i), tx));
        rxs.push(rx);
    }
    // immediate shutdown: drain semantics must finish all 12
    let report = router.shutdown();
    assert_eq!(report.completed(), 12);
    for rx in rxs {
        assert!(rx.recv().is_ok(), "in-flight request lost at shutdown");
    }
}

#[test]
fn concurrent_dispatch_never_overruns_admission_cap() {
    // the shed ledger is check-then-act-free: N threads flooding
    // dispatch must never admit more than queue_cap outstanding
    // requests. The replica is slow enough that nothing completes
    // while the flood is in flight, so `admitted <= cap` is exact.
    let cap = 8usize;
    let specs = vec![SimSpec {
        work_per_module: 500_000,
        lazy_pct: 0,
        ..SimSpec::default()
    }];
    let router = Arc::new(build_router(specs, RoutePolicy::Jsq, cap));
    let threads = 8usize;
    let per = 8usize;
    let mut joins = Vec::new();
    for t in 0..threads {
        let r = router.clone();
        joins.push(std::thread::spawn(move || {
            let mut rxs = Vec::new();
            let mut shed = 0usize;
            for i in 0..per {
                let (tx, rx) = mpsc::channel();
                let req = Request::new(0, 1, 6, (t * per + i) as u64);
                if r.dispatch(req, tx) {
                    rxs.push(rx);
                } else {
                    shed += 1;
                }
            }
            (rxs, shed)
        }));
    }
    let mut rxs = Vec::new();
    let mut shed = 0usize;
    for j in joins {
        let (r, s) = j.join().unwrap();
        rxs.extend(r);
        shed += s;
    }
    // completions during the flood legitimately free admission slots
    // (resolved() grows), so bound by cap + whatever resolved by the
    // time the flood ended — on an unloaded machine that term is 0
    let completed_during_flood = router.total_completed() as usize;
    assert_eq!(rxs.len() + shed, threads * per);
    assert!(rxs.len() <= cap + completed_during_flood,
            "admission overrun: {} admitted with cap {cap} (+{} completed \
             mid-flood)", rxs.len(), completed_during_flood);
    assert!(shed > 0, "a 64-request flood against cap 8 must shed");
    assert_eq!(router.shed_count(), shed as u64);
    for rx in &rxs {
        rx.recv().expect("admitted requests must complete");
    }
    let report = router.shutdown();
    assert_eq!(report.completed(), rxs.len());
    assert_eq!(report.shed, shed as u64);
}

#[test]
fn stealing_migrates_without_losing_or_duplicating_jobs() {
    // skewed pool: replica 0 never skips (slow), replica 1 skips ~90%
    // (fast). With a window of 1 almost everything waits in queues, so
    // the fast replica drains its own share and then must steal the
    // slow replica's stranded jobs.
    let specs = vec![SimSpec::with_lazy(0, 100_000),
                     SimSpec::with_lazy(90, 100_000)];
    let router = build_stealing_router(specs, RoutePolicy::Jsq, 1024, 1);
    let (results, shed) = run_workload(&router, 32, 6);
    assert_eq!(shed, 0);
    assert_eq!(results.len(), 32, "every job answered exactly once");
    let ids: std::collections::BTreeSet<u64> =
        results.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), 32, "no duplicated responses after migration");
    // all responses received → every queued-gauge transfer unwound
    assert_eq!(router.total_queued(), 0,
               "gauges must drain to zero after migrations");
    let report = router.shutdown();
    assert_eq!(report.failed(), 0);
    assert_eq!(report.completed(), 32);
    assert!(report.total_steals() > 0,
            "fast replica must have stolen from the stranded slow one");
    assert_eq!(report.total_steals(), report.total_stolen(),
               "each migration has exactly one thief and one victim");
    // the thief is the lazy replica, the victim the never-skip one
    assert!(report.replicas[1].steals > 0);
    assert!(report.replicas[0].stolen > 0);
    assert!(report.render().contains("stole"),
            "steal counters surface in the pool report");
}

#[test]
fn stealing_preserves_drain_semantics_at_shutdown() {
    // close the pool immediately after flooding: drain + steal must
    // still complete every admitted job exactly once (thieves may pull
    // from closed-but-undrained sibling queues)
    let specs = vec![SimSpec::with_lazy(0, 50_000),
                     SimSpec::with_lazy(90, 50_000)];
    let router = build_stealing_router(specs, RoutePolicy::Jsq, 256, 1);
    let mut rxs = Vec::new();
    for i in 0..16 {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(Request::new(0, 2, 5, 900 + i), tx));
        rxs.push(rx);
    }
    let report = router.shutdown();
    assert_eq!(report.completed(), 16);
    for rx in rxs {
        assert!(rx.recv().is_ok(), "in-flight request lost at shutdown");
    }
    assert_eq!(report.total_steals(), report.total_stolen());
}

#[test]
fn stealing_outputs_stay_deterministic() {
    // migration must not change what any request produces — only where
    let elems = SimSpec::fast().img_elems;
    let reference: BTreeMap<u64, Vec<f32>> = (0..24u64)
        .map(|i| {
            let req = Request::new(0, (i % 10) as usize, 6, 1000 + i);
            (1000 + i, sim_image(&req, elems).data().to_vec())
        })
        .collect();
    let specs = vec![SimSpec::fast(); 3];
    let router = build_stealing_router(specs, RoutePolicy::Lazy, 1024, 2);
    let (results, shed) = run_workload(&router, 24, 6);
    assert_eq!(shed, 0);
    let mut seen = std::collections::BTreeSet::new();
    for r in &results {
        let seed = seed_of(r, &reference);
        assert!(seen.insert(seed), "duplicate image for seed {seed}");
    }
    assert_eq!(seen.len(), 24);
    router.shutdown();
}

/// A mixed-tier pool: replica 0 latency-tier B1, replicas 1..n
/// throughput-tier B8, optionally with stealing armed.
fn build_tiered_router(thr_replicas: usize, route: RoutePolicy,
                       queue_cap: usize, steal: bool) -> Router {
    let rb = steal.then(|| Rebalancer::new(1));
    let mut tiers = vec![ReplicaTier::new(Slo::Latency, 1)];
    tiers.extend((0..thr_replicas)
        .map(|_| ReplicaTier::new(Slo::Throughput, 8)));
    let handles: Vec<ReplicaHandle> = tiers
        .into_iter()
        .enumerate()
        .map(|(i, tier)| {
            ReplicaHandle::spawn_tiered(i, queue_cap,
                                        SimEngine::factory(SimSpec::fast()),
                                        rb.clone(), tier)
            .unwrap()
        })
        .collect();
    Router::with_rebalancer(handles, route, queue_cap, rb)
}

#[test]
fn slo_traffic_lands_on_its_tier_and_sheds_honestly() {
    let router = build_tiered_router(2, RoutePolicy::Jsq, 1024, false);
    let mut rxs = Vec::new();
    for i in 0..30u64 {
        let slo = match i % 3 {
            0 => Slo::Latency,
            1 => Slo::Throughput,
            _ => Slo::Besteffort,
        };
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(0, (i % 10) as usize, 4, 3000 + i)
            .with_slo(slo);
        // single-lane: a B1 latency replica cannot fit a 2-lane CFG
        // request (the router would shed it — see candidate_order)
        req.cfg_scale = 1.0;
        assert!(router.dispatch(req, tx), "cap 1024 must not shed");
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().expect("response");
    }
    let report = router.shutdown();
    assert_eq!(report.completed(), 30);
    assert_eq!(report.failed(), 0);
    // tier isolation: the B1 latency replica never ran a throughput
    // job, and no throughput replica ever ran a latency job
    assert_eq!(report.replicas[0].completed_by_slo[Slo::Throughput.index()],
               0, "latency replica must not serve bulk traffic");
    for r in &report.replicas[1..] {
        assert_eq!(r.completed_by_slo[Slo::Latency.index()], 0,
                   "throughput replica {} must not serve latency traffic",
                   r.id);
    }
    // all 10 latency jobs ran on replica 0 (no best-effort spill target
    // exists in this pool)
    assert_eq!(report.replicas[0].completed_by_slo[Slo::Latency.index()],
               10);
    // per-tier completions sum to the totals
    assert_eq!(report.completed_by_slo().iter().sum::<u64>(), 30);
    assert_eq!(report.shed_by_slo, [0, 0, 0]);
    // the render surfaces the tier breakdown
    let rendered = report.render();
    assert!(rendered.contains("tiers (completed/shed)"), "{rendered}");
}

#[test]
fn slo_tier_isolation_survives_stealing() {
    // stealing on, tiny admit window: the idle throughput replicas will
    // try to steal the latency replica's backlog — the tier constraint
    // must stop latency jobs from migrating onto B8 replicas and
    // vice versa, while best-effort jobs migrate freely
    let router = build_tiered_router(2, RoutePolicy::Jsq, 1024, true);
    let mut rxs = Vec::new();
    for i in 0..48u64 {
        let slo = match i % 3 {
            0 => Slo::Latency,
            1 => Slo::Throughput,
            _ => Slo::Besteffort,
        };
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(0, (i % 10) as usize, 5, 7000 + i)
            .with_slo(slo);
        req.cfg_scale = 1.0; // single-lane: fits the B1 latency tier
        assert!(router.dispatch(req, tx));
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().expect("response");
    }
    let report = router.shutdown();
    assert_eq!(report.completed(), 48);
    assert_eq!(report.total_steals(), report.total_stolen());
    assert_eq!(report.replicas[0].completed_by_slo[Slo::Throughput.index()],
               0, "steal constraint: B1 latency replica took a bulk job");
    for r in &report.replicas[1..] {
        assert_eq!(r.completed_by_slo[Slo::Latency.index()], 0,
                   "steal constraint: B8 replica {} took a latency job",
                   r.id);
    }
}

#[test]
fn latency_requests_shed_when_no_compatible_tier_is_live() {
    // throughput-only pool: a latency request must shed immediately
    // (and be counted against the latency tier), never silently run on
    // a deep-batch replica
    let handles: Vec<ReplicaHandle> = (0..2)
        .map(|i| {
            ReplicaHandle::spawn_tiered(i, 64,
                                        SimEngine::factory(SimSpec::fast()),
                                        None,
                                        ReplicaTier::new(Slo::Throughput, 8))
            .unwrap()
        })
        .collect();
    let router = Router::new(handles, RoutePolicy::Jsq, 64);
    let (tx, rx) = mpsc::channel();
    let mut req = Request::new(0, 1, 4, 1).with_slo(Slo::Latency);
    req.cfg_scale = 1.0;
    // the shed is reported as *unservable* (permanent for this pool
    // shape), not as transient "queue full"
    assert_eq!(router.dispatch_outcome(req, tx),
               lazydit::coordinator::pool::DispatchOutcome::ShedUnservable,
               "no compatible tier → unservable shed");
    assert!(rx.recv().is_err());
    assert_eq!(router.shed_by_slo(), [1, 0, 0]);
    // best-effort traffic still flows
    let (tx, rx) = mpsc::channel();
    assert!(router.dispatch(Request::new(0, 1, 4, 2), tx));
    rx.recv().expect("best-effort response");
    let report = router.shutdown();
    assert_eq!(report.shed, 1);
    assert_eq!(report.shed_by_slo, [1, 0, 0]);
    assert_eq!(report.completed(), 1);
}

#[test]
fn unservable_reason_is_stable_under_capacity_pressure() {
    use lazydit::coordinator::pool::DispatchOutcome;
    // throughput-only pool saturated to its admission bound: a latency
    // request must still shed as *unservable* (permanent), never as
    // "queue full" (transient) — the reason must not flip-flop with
    // instantaneous load
    let handles: Vec<ReplicaHandle> = (0..1)
        .map(|i| {
            ReplicaHandle::spawn_tiered(
                i, 4,
                SimEngine::factory(SimSpec {
                    work_per_module: 500_000,
                    lazy_pct: 0,
                    ..SimSpec::default()
                }),
                None,
                ReplicaTier::new(Slo::Throughput, 8))
            .unwrap()
        })
        .collect();
    let router = Router::new(handles, RoutePolicy::Jsq, 4);
    let mut rxs = Vec::new();
    for i in 0..4u64 {
        let (tx, rx) = mpsc::channel();
        assert_eq!(router.dispatch_outcome(Request::new(0, 1, 6, i), tx),
                   DispatchOutcome::Admitted);
        rxs.push(rx);
    }
    // at the bound: a compatible best-effort request sheds as capacity…
    let (tx, rx_cap) = mpsc::channel();
    assert_eq!(router.dispatch_outcome(Request::new(0, 1, 6, 90), tx),
               DispatchOutcome::ShedCapacity);
    assert!(rx_cap.recv().is_err());
    // …but an incompatible latency request is still unservable
    let (tx, rx_uns) = mpsc::channel();
    let mut req = Request::new(0, 1, 6, 91).with_slo(Slo::Latency);
    req.cfg_scale = 1.0;
    assert_eq!(router.dispatch_outcome(req, tx),
               DispatchOutcome::ShedUnservable);
    assert!(rx_uns.recv().is_err());
    assert_eq!(router.shed_by_slo(), [1, 0, 1]);
    for rx in rxs {
        rx.recv().expect("admitted requests must complete");
    }
    router.shutdown();
}

#[test]
fn stats_verb_reports_live_gauges_over_the_wire() {
    use lazydit::coordinator::server::serve_pool;
    use lazydit::util::json::Json;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let router = build_tiered_router(1, RoutePolicy::Jsq, 64, false);
    let addr = "127.0.0.1:18492";
    let server = std::thread::spawn(move || {
        serve_pool(router, addr, 2).expect("serve_pool")
    });
    let mut stream = None;
    for _ in 0..200 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => {
                std::thread::sleep(std::time::Duration::from_millis(10))
            }
        }
    }
    let stream = stream.expect("server did not come up");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();

    // STATS before any request: a fresh pool, gauges at zero
    writer.write_all(b"STATS\n").unwrap();
    writer.flush().unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).expect("STATS returns valid JSON");
    let reps = j.req("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    assert_eq!(reps[0].req("tier").unwrap().as_str().unwrap(), "latency");
    assert_eq!(reps[0].req("max_batch").unwrap().as_usize().unwrap(), 1);
    assert_eq!(reps[1].req("tier").unwrap().as_str().unwrap(),
               "throughput");
    assert_eq!(j.req("completed").unwrap().as_u64().unwrap(), 0);
    assert!(j.req("shed_by_slo").unwrap().get("latency").is_some());

    // one tagged request round-trips with its SLO echoed
    writer
        .write_all(b"{\"label\": 2, \"steps\": 3, \"seed\": 5, \
                     \"cfg_scale\": 1.0, \"slo\": \"latency\"}\n")
        .unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.req("slo").unwrap().as_str().unwrap(), "latency");
    assert_eq!(j.req("steps").unwrap().as_usize().unwrap(), 3);

    // STATS now shows the completion attributed to the latency tier
    writer.write_all(b"STATS\n").unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.req("completed").unwrap().as_u64().unwrap(), 1);
    let reps = j.req("replicas").unwrap().as_arr().unwrap();
    assert_eq!(
        reps[0]
            .req("completed_by_slo").unwrap()
            .req("latency").unwrap()
            .as_u64().unwrap(),
        1
    );

    // second request releases the serve loop (max_requests = 2)
    writer
        .write_all(b"{\"label\": 1, \"steps\": 3, \"seed\": 6}\n")
        .unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"id\""), "second response: {line}");
    let report = server.join().expect("server thread");
    assert_eq!(report.completed(), 2);
}

#[test]
fn drain_by_migration_relocates_residents_bit_identically() {
    // drain replica 0 while trajectories are mid-flight: every resident
    // must cross to the sibling as a portable snapshot and finish with
    // exactly the image an uninterrupted run would have produced
    let elems = SimSpec::fast().img_elems;
    let reference: BTreeMap<u64, Vec<f32>> = (0..8u64)
        .map(|i| {
            let req = Request::new(0, (i % 10) as usize, 8, 4000 + i);
            (4000 + i, sim_image(&req, elems).data().to_vec())
        })
        .collect();
    let specs = vec![SimSpec::with_lazy(50, 150_000); 2];
    let router =
        build_stealing_router(specs, RoutePolicy::RoundRobin, 1024, 8);
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        let (tx, rx) = mpsc::channel();
        let req = Request::new(0, (i % 10) as usize, 8, 4000 + i);
        assert!(router.dispatch(req, tx));
        rxs.push(rx);
    }
    // re-arm the sweep until it lands on a resident (a sweep that finds
    // an empty engine migrates nothing) — mirrors serve's --drain-after
    let mut migrated = false;
    for _ in 0..2000 {
        router.drain_replica(0);
        std::thread::sleep(std::time::Duration::from_millis(1));
        if router.total_migrated() > 0 {
            migrated = true;
            break;
        }
    }
    let mut seen = std::collections::BTreeSet::new();
    for rx in rxs {
        let r = rx.recv().expect("no request may strand during a drain");
        let seed = seed_of(&r, &reference);
        assert!(seen.insert(seed), "duplicate image for seed {seed}");
    }
    assert_eq!(seen.len(), 8, "a migrated trajectory diverged or was lost");
    let report = router.shutdown();
    assert_eq!(report.completed(), 8);
    assert_eq!(report.failed(), 0);
    assert!(migrated, "drain sweep never caught a resident trajectory");
    assert!(report.total_resumed() >= 1,
            "a migrated snapshot must resume somewhere");
    assert_eq!(report.total_migrated_out(), report.total_migrated_in(),
               "every snapshot that left a replica arrived at exactly one");
    assert_eq!(router.total_forfeited(), 0, "a drain must strand nothing");
    assert!(report.render().contains("migration:"),
            "migration counters surface in the pool report");
}

/// A [`SimEngine`] that panics inside `step_round` after a fixed number
/// of successful rounds — the crash half of crash-resume. Everything
/// else delegates, *including* the snapshot surface, so the worker's
/// between-rounds boundary stash stays fresh right up to the crash.
struct PanickyEngine {
    inner: SimEngine,
    rounds_left: usize,
}

impl lazydit::coordinator::pool::PoolEngine for PanickyEngine {
    fn submit(&mut self, req: Request) -> u64 {
        self.inner.submit(req)
    }
    fn active_count(&self) -> usize {
        self.inner.active_count()
    }
    fn pending_steps(&self) -> usize {
        self.inner.pending_steps()
    }
    fn step_round(&mut self)
                  -> anyhow::Result<Vec<RequestResult>> {
        if self.rounds_left == 0 {
            panic!("injected mid-trajectory crash");
        }
        self.rounds_left -= 1;
        self.inner.step_round()
    }
    fn layer_stats(&self) -> &lazydit::coordinator::stats::LayerStats {
        self.inner.layer_stats()
    }
    fn serve_stats(&self) -> &lazydit::coordinator::stats::ServeStats {
        self.inner.serve_stats()
    }
    fn policy_name(&self) -> String {
        self.inner.policy_name()
    }
    fn active_ids(&self) -> Vec<u64> {
        self.inner.active_ids()
    }
    fn evict_to_snapshot(&mut self, id: u64)
        -> Option<lazydit::coordinator::request::TrajectorySnapshot> {
        self.inner.evict_to_snapshot(id)
    }
    fn admit_snapshot(
        &mut self,
        snap: lazydit::coordinator::request::TrajectorySnapshot) -> u64 {
        self.inner.admit_snapshot(snap)
    }
    fn snapshot_request(&self, id: u64)
        -> Option<lazydit::coordinator::request::TrajectorySnapshot> {
        self.inner.snapshot_request(id)
    }
}

#[test]
fn crashed_replica_residents_resume_on_siblings_from_last_boundary() {
    let elems = SimSpec::fast().img_elems;
    let reference: BTreeMap<u64, Vec<f32>> = (0..6u64)
        .map(|i| {
            let req = Request::new(0, (i % 10) as usize, 10, 6000 + i);
            (6000 + i, sim_image(&req, elems).data().to_vec())
        })
        .collect();
    // replica 0 dies on its 4th working round; replica 1 is healthy.
    // Heavy per-module work keeps each round ~milliseconds so all six
    // dispatches land well before the injected crash.
    let rb = Rebalancer::new(8);
    let crashy: lazydit::coordinator::pool::EngineFactory =
        Box::new(|| {
            Ok(Box::new(PanickyEngine {
                inner: SimEngine::new(SimSpec::with_lazy(50, 100_000)),
                rounds_left: 3,
            }) as Box<dyn PoolEngine>)
        });
    let handles = vec![
        ReplicaHandle::spawn_with(0, 256, crashy, Some(rb.clone())).unwrap(),
        ReplicaHandle::spawn_with(
            1, 256, SimEngine::factory(SimSpec::with_lazy(50, 100_000)),
            Some(rb.clone())).unwrap(),
    ];
    let router =
        Router::with_rebalancer(handles, RoutePolicy::RoundRobin, 256,
                                Some(rb));
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let (tx, rx) = mpsc::channel();
        let req = Request::new(0, (i % 10) as usize, 10, 6000 + i);
        assert!(router.dispatch(req, tx));
        rxs.push(rx);
    }
    // every request — including replica 0's residents at crash time —
    // must complete, and the resumed ones bit-identically to an
    // uninterrupted run (the partially-crashed round replays from the
    // last boundary snapshot, never from torn mid-round state)
    let mut seen = std::collections::BTreeSet::new();
    for rx in rxs {
        let r = rx.recv().expect("resident lost to the crash");
        let seed = seed_of(&r, &reference);
        assert!(seen.insert(seed), "duplicate image for seed {seed}");
    }
    assert_eq!(seen.len(), 6);
    let report = router.shutdown();
    assert_eq!(report.completed(), 6);
    assert_eq!(report.failed(), 1, "the crashed replica reports failure");
    assert!(report.total_resumed() >= 1,
            "at least one resident must resume from its boundary snapshot");
    assert!(report.total_resume_steps_saved() >= 1,
            "resuming from a boundary snapshot saves the completed steps");
    assert_eq!(router.total_forfeited(), 0,
               "with a live sibling, a crash forfeits nothing");
}

#[test]
fn jsq_balances_across_replicas() {
    let specs = vec![SimSpec::fast(); 4];
    let router = build_router(specs, RoutePolicy::Jsq, 1024);
    let (results, _) = run_workload(&router, 40, 6);
    assert_eq!(results.len(), 40);
    let report = router.shutdown();
    // JSQ's tie-break walks the pool before reusing a replica, so with
    // 40 instant arrivals nobody can be starved outright
    for r in &report.replicas {
        assert!(r.serve.completed >= 1,
                "replica {} served nothing", r.id);
    }
    assert_eq!(report.completed(), 40);
}

#[test]
fn result_cache_hits_and_warm_starts_end_to_end() {
    use lazydit::coordinator::pool::{CacheConfig, PoolCache};
    use lazydit::obs::Tracer;

    let spec = SimSpec::fast();
    let elems = spec.img_elems;
    // capacity 32, warm horizon 2, model fingerprint 7 (arbitrary but
    // shared by the router-side key and the replica-side insert key)
    let cache = Arc::new(PoolCache::new(CacheConfig::new(32, 2, 7)));
    let handles = vec![ReplicaHandle::spawn_cached(
        0, 64, SimEngine::factory(spec), None, ReplicaTier::default(),
        Tracer::disabled(), Some(cache.clone()))
        .unwrap()];
    let router = Router::with_cache(handles, RoutePolicy::RoundRobin, 64,
                                    None, Some(cache.clone()));
    let send = |label: usize, steps: usize, seed: u64| {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(Request::new(0, label, steps, seed), tx));
        rx.recv().expect("response")
    };

    // engine-served miss, then a byte-identical zero-latency exact hit
    let a = send(3, 6, 42);
    assert_eq!(a.image.data(),
               sim_image(&Request::new(0, 3, 6, 42), elems).data());
    let b = send(3, 6, 42);
    assert_eq!(router.total_cache_hits(), 1, "exact repeat must hit");
    assert_eq!(b.image.data(), a.image.data(),
               "a cache hit serves the engine's bytes");
    assert_eq!(b.latency, std::time::Duration::ZERO,
               "hits never enter the latency accounting");
    assert_ne!(b.id, a.id, "a hit still gets its own wire id");

    // same family, different seed: a warm start, not a hit — and the
    // output is still this request's own (seed-correct) image
    let c = send(3, 6, 43);
    assert_eq!(router.total_cache_hits(), 1, "near hit is not an exact hit");
    assert_eq!(router.total_warm_hits(), 1, "near hit warm-starts");
    assert!(router.total_rows_warmed() > 0,
            "the donor must actually seed rows");
    assert_eq!(c.image.data(),
               sim_image(&Request::new(0, 3, 6, 43), elems).data(),
               "warm start must not change the output");

    // the conservation law with the cache term:
    // dispatched == completed + cache_hits + shed + forfeited
    let dispatched = router.total_dispatched();
    let hits = router.total_cache_hits();
    let forfeited = router.total_forfeited();
    let report = router.shutdown();
    assert_eq!(report.cache_hits, hits);
    assert_eq!(dispatched,
               report.completed() as u64 + hits + report.shed + forfeited);
    assert_eq!(report.completed(), 2, "only the misses reached the engine");
    assert!(report.render().contains("cache: 1 exact hits"),
            "the report surfaces cache work:\n{}", report.render());
}

#[test]
fn per_replica_policy_labels_surface_in_report() {
    let specs = vec![
        SimSpec { policy: "mean".into(), lazy_pct: 90, ..SimSpec::fast() },
        SimSpec { policy: "never".into(), lazy_pct: 0, ..SimSpec::fast() },
    ];
    let router = build_router(specs, RoutePolicy::RoundRobin, 64);
    let (results, _) = run_workload(&router, 8, 4);
    assert_eq!(results.len(), 8);
    let report = router.shutdown();
    let labels: Vec<&str> =
        report.replicas.iter().map(|r| r.policy.as_str()).collect();
    assert_eq!(labels, vec!["mean", "never"]);
    // the never replica must report Γ = 0 — the A/B contrast is real
    assert_eq!(report.replicas[1].layer.overall_ratio(), 0.0);
    assert!(report.replicas[0].layer.overall_ratio() > 0.0);
    let rendered = report.render();
    assert!(rendered.contains("mean") && rendered.contains("never"));
}
