//! Serving-path integration: the TCP JSON-lines server end-to-end — admit,
//! batch, respond — plus admission-control shedding.

use lazydit::config::{ServeConfig, SkipPolicy, TrainConfig};
use lazydit::coordinator::engine::{Engine, EngineOptions};
use lazydit::coordinator::server;
use lazydit::model::checkpoint::Checkpoint;
use lazydit::model::runner::ModelRunner;
use lazydit::runtime::engine_rt::Runtime;
use lazydit::runtime::manifest::Manifest;
use lazydit::train::pretrain::pretrain;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::rc::Rc;

#[test]
fn tcp_server_roundtrip() {
    let root = PathBuf::from("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&root).unwrap();
    let Ok(cfg) = manifest.config("nano") else {
        eprintln!("skipping: nano not exported");
        return;
    };
    let cfg = cfg.clone();
    let dir = std::env::temp_dir().join("lazydit_serve_test");
    std::fs::create_dir_all(&dir).unwrap();

    // server thread owns the engine (PJRT types are not Send/Sync)
    let addr = "127.0.0.1:18471";
    let server_thread = std::thread::spawn(move || {
        let rt = Rc::new(Runtime::cpu().unwrap());
        let tc = TrainConfig { config_name: "nano".into(), steps: 2, lr: 1e-3,
                               ..Default::default() };
        let _ = pretrain(&rt, &cfg, &tc, &dir).unwrap();
        let theta = Checkpoint::load(
            &lazydit::model::checkpoint::theta_path(&dir, "nano"))
            .unwrap().vec("theta").unwrap().clone();
        let runner =
            ModelRunner::with_disabled_gates(rt, cfg, &theta).unwrap();
        let engine = Engine::from_parts(
            runner,
            ServeConfig { config_name: "nano".into(), max_batch: 4,
                          policy: SkipPolicy::Never, ..Default::default() },
            EngineOptions::default(),
        );
        // serve exactly 3 requests then return
        server::serve(engine, addr, 3).unwrap();
    });

    // wait for the listener (engine construction compiles graphs first)
    let mut stream = None;
    for _ in 0..900 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    for (i, label) in [1usize, 4, 7].iter().enumerate() {
        let req = format!(
            "{{\"label\": {label}, \"steps\": 4, \"seed\": {i}}}\n");
        writer.write_all(req.as_bytes()).unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = lazydit::util::json::Json::parse(line.trim()).unwrap();
        assert_eq!(j.req("label").unwrap().as_usize().unwrap(), *label);
        assert_eq!(j.req("steps").unwrap().as_usize().unwrap(), 4);
        assert!(j.req("latency_ms").unwrap().as_f64().unwrap() > 0.0);
    }
    server_thread.join().unwrap();
}

#[test]
fn malformed_requests_get_errors() {
    // pure protocol check, no engine needed
    assert!(server::parse_request_line("garbage").is_err());
    assert!(server::parse_request_line("{}").is_err());
    let ok = server::parse_request_line(r#"{"label": 2}"#).unwrap();
    assert_eq!(ok.class_label, 2);
}
