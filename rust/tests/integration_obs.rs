//! Observability integration: the STATS/TRACE verbs end-to-end over a
//! traced synthetic pool (per-tier histogram quantiles, ring-event
//! payloads, overwrite accounting) and the Chrome-trace export path
//! (docs/OBSERVABILITY.md).

use lazydit::config::RoutePolicy;
use lazydit::coordinator::pool::replica::{ReplicaHandle, ReplicaTier};
use lazydit::coordinator::pool::sim::{SimEngine, SimSpec};
use lazydit::coordinator::pool::Router;
use lazydit::coordinator::request::Request;
use lazydit::coordinator::server;
use lazydit::obs::chrome::{collect_tracers, validate_chrome_trace,
                           write_chrome_trace};
use lazydit::obs::Tracer;
use lazydit::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn sim_spec() -> SimSpec {
    SimSpec { lazy_pct: 50, work_per_module: 500, ..SimSpec::default() }
}

/// One traced replica per entry in `ring_caps`, default (best-effort)
/// tier, jsq routing. Returns the pool plus the tracer clones that
/// `serve --trace-out` would hold for shutdown export.
fn spawn_traced_pool(ring_caps: &[usize]) -> (Router, Vec<Tracer>) {
    let mut tracers = Vec::with_capacity(ring_caps.len());
    let handles: Vec<ReplicaHandle> = ring_caps
        .iter()
        .enumerate()
        .map(|(i, &cap)| {
            let tracer = Tracer::enabled(i, cap);
            tracers.push(tracer.clone());
            ReplicaHandle::spawn_traced(i, 64, SimEngine::factory(sim_spec()),
                                        None, ReplicaTier::default(), tracer)
                .unwrap()
        })
        .collect();
    (Router::new(handles, RoutePolicy::Jsq, 64), tracers)
}

fn connect(addr: &str) -> TcpStream {
    for _ in 0..900 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    panic!("server did not come up on {addr}");
}

fn quantile_block<'a>(parent: &'a Json, key: &str) -> &'a Json {
    let block = parent.get(key)
        .unwrap_or_else(|| panic!("missing {key} block"));
    for field in ["count", "mean_ms", "p50", "p95", "p99"] {
        assert!(block.get(field).and_then(|v| v.as_f64()).is_some(),
                "{key} block missing numeric {field}");
    }
    block
}

#[test]
fn stats_and_trace_roundtrip_over_traced_pool() {
    let (router, _tracers) = spawn_traced_pool(&[4096, 4096]);
    let addr = "127.0.0.1:18494";
    let total = 6usize;
    let server_thread = std::thread::spawn(move || {
        server::serve_pool(router, addr, total).unwrap()
    });

    let stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut send = |line: &str| {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).expect("response json")
    };

    // drive all but one request to completion, cycling SLO classes so
    // more than one tier histogram fills (a best-effort pool serves all
    // three classes — latency/throughput land as spill)
    let classes = ["besteffort", "latency", "throughput"];
    for i in 0..total - 1 {
        let resp = send(&format!(
            "{{\"label\": {}, \"steps\": 4, \"seed\": {i}, \
             \"cfg_scale\": 1.0, \"slo\": \"{}\"}}",
            i % 10, classes[i % classes.len()]));
        assert!(resp.get("error").is_none(), "request {i} errored: {resp}");
        assert!(resp.req("latency_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    // STATS: per-replica latency_ms + pool-wide per-tier quantiles from
    // the merged histograms. Latency/Retire land in the gauges before
    // the response is sent, so counters cover every response read above.
    let stats = send("STATS");
    let replicas = stats.req("replicas").unwrap().as_arr().unwrap();
    assert_eq!(replicas.len(), 2);
    for r in replicas {
        quantile_block(r, "latency_ms");
    }
    let tiers = stats.req("tiers").unwrap();
    let mut tier_count = 0.0;
    for class in classes {
        let block = quantile_block(tiers, class);
        let count = block.req("count").unwrap().as_f64().unwrap();
        tier_count += count;
        if count > 0.0 {
            assert!(block.req("p99").unwrap().as_f64().unwrap()
                    >= block.req("p50").unwrap().as_f64().unwrap(),
                    "{class}: p99 below p50");
            assert!(block.req("p50").unwrap().as_f64().unwrap() > 0.0,
                    "{class}: served but zero p50");
        }
    }
    let completed = stats.req("completed").unwrap().as_f64().unwrap();
    assert_eq!(tier_count, completed,
               "per-tier histogram counts must partition completions");
    assert!(completed >= (total - 1) as f64);

    // TRACE: enabled, and every event kind of a request's lifecycle is
    // present with the typed payload fields (rings are far larger than
    // the event volume here, so nothing has been overwritten and the
    // all-time count must equal the surviving events exactly)
    let trace = send("TRACE");
    assert_eq!(trace.req("enabled").unwrap(), &Json::Bool(true));
    let treps = trace.req("replicas").unwrap().as_arr().unwrap();
    assert_eq!(treps.len(), 2);
    let mut kinds: Vec<String> = Vec::new();
    for r in treps {
        let recorded = r.req("recorded").unwrap().as_u64().unwrap();
        let events = r.req("events").unwrap().as_arr().unwrap();
        assert_eq!(recorded, events.len() as u64,
                   "unwrapped ring must surface its full history");
        for ev in events {
            for field in ["ts_us", "dur_us", "id", "arg"] {
                assert!(ev.req(field).unwrap().as_f64().is_some());
            }
            kinds.push(ev.req("kind").unwrap().as_str().unwrap().to_string());
        }
    }
    for expected in ["admit", "batch_build", "retire"] {
        assert!(kinds.iter().any(|k| k == expected),
                "no {expected} event in TRACE (got {kinds:?})");
    }
    assert!(kinds.iter().any(|k| k == "module_run" || k == "module_skip"),
            "no per-module events in TRACE");

    // final request releases the server's completion bound
    let resp = send(
        "{\"label\": 9, \"steps\": 4, \"seed\": 99, \"cfg_scale\": 1.0, \
         \"slo\": \"besteffort\"}");
    assert!(resp.get("error").is_none());
    let report = server_thread.join().unwrap();
    assert!(report.completed() >= total);
}

fn drive(router: &Router, requests: usize) {
    let mut rxs = Vec::new();
    for i in 0..requests {
        let (tx, rx) = std::sync::mpsc::channel();
        assert!(router.dispatch(Request::new(i as u64, i % 10, 4,
                                             1000 + i as u64),
                                tx));
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().expect("response");
    }
}

#[test]
fn wrapped_ring_keeps_counting_but_bounds_events() {
    // a request's lifecycle is dozens of events; a tiny ring must wrap
    let small = 8usize;
    let (router, _tracers) = spawn_traced_pool(&[small]);
    drive(&router, 3);
    let trace = Json::parse(&router.trace_json(512)).unwrap();
    let rep = &trace.req("replicas").unwrap().as_arr().unwrap()[0];
    let recorded = rep.req("recorded").unwrap().as_u64().unwrap();
    let events = rep.req("events").unwrap().as_arr().unwrap();
    assert!(recorded > small as u64, "workload too small to wrap the ring");
    assert!(events.len() <= small,
            "wrapped ring surfaced more events than its capacity");
    assert!(recorded > events.len() as u64,
            "overwrite must drop payloads but never the count");
    // the survivors are the newest window: the final retire is in it
    let kinds: Vec<&str> = events
        .iter()
        .map(|e| e.req("kind").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(kinds.last().copied(), Some("retire"));
    // timestamps stay monotone across the wrap
    let ts: Vec<f64> = events
        .iter()
        .map(|e| e.req("ts_us").unwrap().as_f64().unwrap())
        .collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]),
            "snapshot must come back oldest-first");
    router.shutdown();
}

#[test]
fn traced_pool_exports_a_valid_chrome_trace() {
    // no TCP here: drive the router directly, then export the rings the
    // way `serve --trace-out` does at shutdown
    let (router, tracers) = spawn_traced_pool(&[4096, 4096]);
    drive(&router, 4);
    router.shutdown();

    let groups = collect_tracers(&tracers, 4096);
    assert_eq!(groups.len(), 2);
    let dir = std::env::temp_dir().join("lazydit_obs_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let summary = write_chrome_trace(&path, &groups).unwrap();
    assert!(summary.slices > 0, "no duration slices recorded");
    assert!(summary.instants > 0, "no instant events recorded");
    assert!(summary.tracks >= 1);

    // what landed on disk re-validates independently and carries the
    // per-replica track names and the retire instants
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(validate_chrome_trace(&text).unwrap(), summary);
    assert!(text.contains("\"thread_name\""));
    assert!(text.contains("\"retire\""));
    std::fs::remove_file(&path).ok();
}

#[test]
fn untraced_pool_reports_trace_disabled() {
    let handles: Vec<ReplicaHandle> = (0..2)
        .map(|i| {
            ReplicaHandle::spawn_tiered(i, 64, SimEngine::factory(sim_spec()),
                                        None, ReplicaTier::default())
                .unwrap()
        })
        .collect();
    let router = Router::new(handles, RoutePolicy::Jsq, 64);
    let trace = Json::parse(&router.trace_json(64)).unwrap();
    assert_eq!(trace.req("enabled").unwrap(), &Json::Bool(false));
    for r in trace.req("replicas").unwrap().as_arr().unwrap() {
        assert_eq!(r.req("recorded").unwrap().as_u64().unwrap(), 0);
        assert!(r.req("events").unwrap().as_arr().unwrap().is_empty());
    }
    // collecting from disabled tracers yields no Chrome groups either
    assert!(collect_tracers(&[Tracer::disabled()], 64).is_empty());
    router.shutdown();
}
