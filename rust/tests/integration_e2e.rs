//! End-to-end integration on the `nano` config: pretrain a few steps (loss
//! must drop), train gates (laziness must rise), then serve batched
//! requests through the full coordinator and check the accounting.

use lazydit::config::{LazyScope, ServeConfig, SkipPolicy, TrainConfig};
use lazydit::coordinator::engine::{generate_batch, Engine, EngineOptions};
use lazydit::coordinator::request::Request;
use lazydit::model::checkpoint::Checkpoint;
use lazydit::model::runner::ModelRunner;
use lazydit::runtime::engine_rt::Runtime;
use lazydit::runtime::manifest::Manifest;
use lazydit::train::lazytrain::{lazy_train, LazyTrainOptions};
use lazydit::train::pretrain::pretrain;
use std::path::PathBuf;
use std::rc::Rc;

fn setup() -> Option<(Rc<Runtime>, lazydit::runtime::manifest::ManifestConfig, PathBuf)> {
    let root = PathBuf::from("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping e2e: artifacts/ not built");
        return None;
    }
    let manifest = Manifest::load(&root).unwrap();
    let Ok(cfg) = manifest.config("nano") else {
        eprintln!("skipping e2e: nano not exported");
        return None;
    };
    let rt = Rc::new(Runtime::cpu().unwrap());
    let dir = std::env::temp_dir().join("lazydit_e2e_test");
    std::fs::create_dir_all(&dir).unwrap();
    Some((rt, cfg.clone(), dir))
}

fn serve_cfg(policy: SkipPolicy) -> ServeConfig {
    ServeConfig {
        config_name: "nano".into(),
        max_batch: 4,
        policy,
        ..Default::default()
    }
}

#[test]
fn full_stack_pretrain_lazytrain_serve() {
    let Some((rt, cfg, dir)) = setup() else { return };

    // ---- phase 1: pretrain (loss must decrease)
    let tc = TrainConfig {
        config_name: "nano".into(),
        steps: 40,
        lr: 3e-3,
        ..Default::default()
    };
    let report = pretrain(&rt, &cfg, &tc, &dir).unwrap();
    assert!(
        report.tail_loss < report.first_loss,
        "pretrain loss must decrease: {} -> {}",
        report.first_loss,
        report.tail_loss
    );
    let theta = Checkpoint::load(&lazydit::model::checkpoint::theta_path(&dir, "nano"))
        .unwrap()
        .vec("theta")
        .unwrap()
        .clone();

    // ---- phase 2: lazy learning (laziness must rise under the controller)
    let ltc = TrainConfig {
        config_name: "nano".into(),
        steps: 60,
        lr: 2e-2,
        ..Default::default()
    };
    let opts = LazyTrainOptions {
        serve_steps: 8,
        target_attn: Some(0.5),
        target_ffn: Some(0.5),
        scope: LazyScope::Both,
        tag: "e2e".into(),
        adjust_every: 5,
    };
    let lrep = lazy_train(&rt, &cfg, &ltc, &opts, &theta, &dir).unwrap();
    assert!(
        lrep.mean_s_attn > 0.12 || lrep.mean_s_ffn > 0.12,
        "gates should move toward laziness: s = {}/{}",
        lrep.mean_s_attn,
        lrep.mean_s_ffn
    );
    let gamma = Checkpoint::load(&lazydit::model::checkpoint::gates_path(
        &dir, "nano", "e2e"))
        .unwrap()
        .vec("gamma")
        .unwrap()
        .clone();

    // ---- phase 3: serve through the coordinator (DDIM baseline)
    let runner = ModelRunner::with_disabled_gates(rt.clone(), cfg.clone(),
                                                  &theta).unwrap();
    let mut engine = Engine::from_parts(runner, serve_cfg(SkipPolicy::Never),
                                        EngineOptions::default());
    let results = generate_batch(&mut engine, &[0, 1, 2], 6, 7, 1.5).unwrap();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert_eq!(r.image.shape(), &[3, 8, 8]);
        assert_eq!(r.lazy_ratio, 0.0, "DDIM path must not skip");
        assert!(r.image.data().iter().all(|v| v.is_finite()));
    }
    // distinct labels (and seeds) ⇒ distinct images
    assert!(results[0].image.sub(&results[1].image).max_abs() > 1e-6);

    // ---- phase 4: serve with trained gates, aggressive policy
    let runner = ModelRunner::new(rt.clone(), cfg.clone(), &theta, &gamma).unwrap();
    let mut engine = Engine::from_parts(runner, serve_cfg(SkipPolicy::Any),
                                        EngineOptions::default());
    let results = generate_batch(&mut engine, &[0, 1, 2, 3], 8, 9, 1.5).unwrap();
    assert_eq!(results.len(), 4);
    let stats = &engine.layer_stats;
    // row-weighted: a partially-skipped slot counts at the engine even
    // when no whole-module invocation was elided (per-request skip
    // counts are per-row too, so the two sides agree)
    assert_eq!(
        stats.row_overall_ratio() > 0.0,
        results.iter().any(|r| r.lazy_ratio > 0.0),
        "engine and per-request accounting must agree on whether skips happened"
    );
    // per-module accounting sums to overall
    for r in &results {
        let per_mod_mean: f64 =
            r.per_module_skip.iter().sum::<f64>() / r.per_module_skip.len() as f64;
        assert!((per_mod_mean - r.lazy_ratio).abs() < 1e-9);
    }

    // ---- phase 5: static-schedule path (Learn2Cache baseline plumbing)
    let slots = 2 * cfg.model.depth;
    let mut sched = vec![vec![false; slots]; 6];
    for row in sched.iter_mut().skip(1) {
        for s in row.iter_mut() {
            *s = true;
        }
    }
    let runner = ModelRunner::with_disabled_gates(rt.clone(), cfg.clone(),
                                                  &theta).unwrap();
    let mut engine = Engine::from_parts(
        runner,
        serve_cfg(SkipPolicy::Never),
        EngineOptions { disable_gates: true, static_schedule: Some(sched) },
    );
    let results = generate_batch(&mut engine, &[4], 6, 11, 1.5).unwrap();
    // 5 of 6 steps skip everything: lazy ratio = 5/6
    let expect = 5.0 / 6.0;
    assert!(
        (results[0].lazy_ratio - expect).abs() < 1e-9,
        "static schedule lazy ratio {} != {expect}",
        results[0].lazy_ratio
    );
}

#[test]
fn continuous_batching_mixed_steps() {
    let Some((rt, cfg, dir)) = setup() else { return };
    // a throwaway θ is fine here — this exercises scheduling, not quality
    let tc = TrainConfig { config_name: "nano".into(), steps: 2, lr: 1e-3,
                           ..Default::default() };
    let _ = pretrain(&rt, &cfg, &tc, &dir).unwrap();
    let theta = Checkpoint::load(&lazydit::model::checkpoint::theta_path(&dir, "nano"))
        .unwrap().vec("theta").unwrap().clone();
    let runner = ModelRunner::with_disabled_gates(rt, cfg, &theta).unwrap();
    let mut engine = Engine::from_parts(runner, serve_cfg(SkipPolicy::Never),
                                        EngineOptions::default());
    // requests with DIFFERENT step counts in one engine (continuous batching)
    for (i, steps) in [4usize, 6, 8].iter().enumerate() {
        let mut req = Request::new(0, i, *steps, i as u64);
        req.cfg_scale = 1.5;
        engine.submit(req);
    }
    let mut done = Vec::new();
    let mut rounds = 0;
    while engine.active_count() > 0 {
        done.extend(engine.step_round().unwrap());
        rounds += 1;
        assert!(rounds < 100, "scheduler must terminate");
    }
    assert_eq!(done.len(), 3);
    // shorter requests must finish no later than longer ones
    done.sort_by_key(|r| r.steps);
    assert_eq!(done[0].steps, 4);
    assert_eq!(done[2].steps, 8);
}
