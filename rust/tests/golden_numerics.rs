//! Cross-layer golden numerics: every exported graph executed from Rust on
//! the python-dumped inputs must reproduce the python outputs (DESIGN.md §8).
//! This is THE correctness contract of the AOT bridge.

use lazydit::runtime::engine_rt::Runtime;
use lazydit::runtime::manifest::Manifest;
use lazydit::runtime::value::HostValue;
use lazydit::sampler::schedule::Schedule;
use lazydit::tensor::Tensor;
use lazydit::util::npy::{self, NpyData};
use std::path::{Path, PathBuf};
use std::rc::Rc;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping golden tests: artifacts/ not built");
        None
    }
}

fn load_input(path: &Path) -> HostValue {
    let arr = npy::read(path).expect("golden input");
    match arr.data {
        NpyData::F32(v) => {
            HostValue::F32(Tensor::from_vec(&arr.shape, v).unwrap())
        }
        NpyData::I32(v) => HostValue::I32 { shape: arr.shape, data: v },
        NpyData::U32(v) => HostValue::U32 { shape: arr.shape, data: v },
        NpyData::F64(v) => HostValue::F32(
            Tensor::from_vec(&arr.shape, v.iter().map(|&x| x as f32).collect())
                .unwrap(),
        ),
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn schedule_matches_python() {
    let Some(root) = artifacts() else { return };
    let golden = npy::read(&root.join("alphas_bar.npy")).unwrap().to_f32();
    let s = Schedule::linear(golden.len(), 1e-4, 2e-2);
    let diff = max_abs_diff(&s.alphas_bar, &golden);
    assert!(diff < 1e-6, "alphas_bar mismatch: {diff}");
}

#[test]
fn all_goldened_graphs_match() {
    let Some(root) = artifacts() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let rt = Rc::new(Runtime::cpu().unwrap());
    let mut checked = 0;
    for (cfg_name, cfg) in &manifest.configs {
        let gdir = root.join("goldens").join(cfg_name);
        if !gdir.exists() {
            continue;
        }
        for (gname, gmeta) in &cfg.graphs {
            let in0 = gdir.join(format!("{gname}.in0.npy"));
            if !in0.exists() {
                continue; // no goldens dumped for this graph
            }
            let exe = rt.load(cfg, gname).unwrap();
            let args: Vec<HostValue> = (0..gmeta.inputs.len())
                .map(|i| load_input(&gdir.join(format!("{gname}.in{i}.npy"))))
                .collect();
            let outs = exe
                .call(&args)
                .unwrap_or_else(|e| panic!("executing {cfg_name}/{gname}: {e:#}"));
            assert_eq!(outs.len(), gmeta.outputs.len(),
                       "{cfg_name}/{gname}: output arity");
            for (i, out) in outs.iter().enumerate() {
                let want =
                    npy::read(&gdir.join(format!("{gname}.out{i}.npy"))).unwrap();
                let got = match out {
                    HostValue::F32(t) => t.data().to_vec(),
                    HostValue::I32 { data, .. } => {
                        data.iter().map(|&v| v as f32).collect()
                    }
                    HostValue::U32 { data, .. } => {
                        data.iter().map(|&v| v as f32).collect()
                    }
                };
                let wantv = want.to_f32();
                assert_eq!(got.len(), wantv.len(),
                           "{cfg_name}/{gname} out{i}: length");
                let diff = max_abs_diff(&got, &wantv);
                // fp32 reassociation differs between jaxlib's XLA and
                // xla_extension 0.5.1; gradient graphs (sign-like AdamW
                // updates) amplify it, so they get a looser bound.
                let scale = wantv.iter().fold(1.0f32, |m, v| m.max(v.abs()));
                let tol = if gname.contains("step") { 2e-3 } else { 1e-4 };
                assert!(diff <= tol * scale.max(1.0),
                        "{cfg_name}/{gname} out{i}: max diff {diff} (scale {scale})");
            }
            checked += 1;
        }
    }
    assert!(checked >= 5, "too few goldened graphs found ({checked})");
    eprintln!("golden-checked {checked} graphs");
}
