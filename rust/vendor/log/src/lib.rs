//! Offline shim for the `log` facade — the subset this workspace uses:
//! `Level`, `LevelFilter`, `Metadata`, `Record`, the `Log` trait,
//! `set_boxed_logger` / `set_max_level` / `max_level`, and the
//! `error!`..`trace!` macros.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one record. Ordered `Error < Warn < Info < Debug < Trace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Global verbosity ceiling. `Off` disables everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Record metadata: level + target (module path of the callsite).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, borrowed for the duration of the `Log::log` call.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Logger implementations receive enabled records.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger. Errors if one is already set.
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not part of the public `log` API.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn macros_do_not_panic_without_logger() {
        info!("hello {}", 1);
        warn!("warn");
        error!("error {x}", x = 2);
        debug!("debug");
        trace!("trace");
    }
}
