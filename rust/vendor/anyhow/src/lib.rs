//! Offline shim for the `anyhow` crate — the subset this workspace uses:
//! `Result`, `Error`, `bail!` / `ensure!` / `anyhow!`, and the `Context`
//! extension trait on `Result` and `Option`.
//!
//! An error is a stack of messages, outermost context first. `{e}` prints
//! the outermost message; `{e:#}` prints the whole chain joined by `: `
//! (matching anyhow's alternate formatting, which `main.rs` relies on).

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// the real crate, so `anyhow::Result<T>` and `Result<T, E>` both work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error. `stack[0]` is the outermost message.
pub struct Error {
    stack: Vec<String>,
}

impl Error {
    /// Build from any displayable message (the `anyhow!` macro body).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { stack: vec![message.to_string()] }
    }

    /// Build from a std error, capturing its source chain.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut stack = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            stack.push(s.to_string());
            src = s.source();
        }
        Error { stack }
    }

    fn push_context(mut self, context: String) -> Error {
        self.stack.insert(0, context);
        self
    }

    /// Iterate the message chain, outermost first (anyhow's `chain()`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.stack.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.stack.join(": "))
        } else {
            write!(f, "{}", self.stack.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.stack.split_first() {
            None => Ok(()),
            Some((top, rest)) => {
                write!(f, "{top}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Internal unification of "things `.context()` can wrap": std errors and
/// `Error` itself. Mirrors anyhow's private `ext::StdError` trick — the
/// concrete impl for `Error` is coherent with the blanket impl because
/// `Error` (a local type) does not implement `std::error::Error`.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::new(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Context extension on `Result` and `Option` (anyhow-compatible).
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().push_context(context.to_string())),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().push_context(f().to_string())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = Err::<(), _>(e).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(7).context("x").unwrap(), 7);
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: disk on fire");
    }

    #[test]
    fn context_on_anyhow_error() {
        let e = Error::msg("inner");
        let e = Err::<(), _>(e).context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(format!("{e}"), "plain msg");
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<u32> {
            let n: u32 = "12x".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn chain_and_root_cause() {
        let e: Error = io_err().into();
        let e = Err::<(), _>(e).context("ctx").unwrap_err();
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause(), "disk on fire");
    }
}
