//! API-compatible stub of the `xla_extension` 0.5.x Rust bindings.
//!
//! Everything host-side is fully functional: `Literal` construction,
//! reshape, typed readback, and `PjRtBuffer` as a host-resident literal
//! holder. The two operations that need the native XLA runtime —
//! `PjRtClient::compile` and executable dispatch — return
//! `Error::Unavailable` with a pointer at the swap instructions below.
//!
//! To serve real models, replace the `xla` path dependency in the root
//! `Cargo.toml` with the actual bindings (github.com/LaurentMazare/xla-rs
//! or the xla_extension build documented in DESIGN.md §2); the L3 crate
//! compiles unmodified against either because it only uses this surface.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error type; `Unavailable` marks operations needing native XLA.
#[derive(Debug)]
pub enum Error {
    Unavailable(String),
    Shape(String),
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(m) => write!(f, "xla stub: {m}"),
            Error::Shape(m) => write!(f, "xla shape error: {m}"),
            Error::Io(e) => write!(f, "xla io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(op: &str) -> Result<T> {
    Err(Error::Unavailable(format!(
        "{op} requires the native XLA runtime — swap rust/vendor/xla for \
         the real xla_extension bindings (see rust/vendor/xla/src/lib.rs)"
    )))
}

/// Element types mirrored from xla_extension (subset + placeholders).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Typed host storage behind a `Literal`.
#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::U32(_) => ElementType::U32,
        }
    }
}

/// Host scalar types storable in a `Literal`.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }

    fn unwrap(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }

    fn unwrap(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn wrap(data: Vec<u32>) -> Data {
        Data::U32(data)
    }

    fn unwrap(data: &Data) -> Option<Vec<u32>> {
        match data {
            Data::U32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Dims + element type of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-resident typed array (fully functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Same storage, new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.data.ty(), dims: self.dims.clone() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Typed readback; errors on a type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error::Shape(format!("literal holds {:?}", self.data.ty()))
        })
    }

    /// Stub literals are always arrays, never tuples.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::Shape("literal is not a tuple".to_string()))
    }
}

/// Parsed HLO module (stub: retains the text for diagnostics).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an `.hlo.txt` dump. File errors are real; parsing is deferred
    /// to the native runtime, which the stub does not have.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(Error::Io)?;
        Ok(HloModuleProto { text })
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: () }
    }
}

/// PJRT client handle (stub: host-only, cannot compile).
#[derive(Clone)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-host".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    /// Host upload — in the stub, a buffer is just a host literal.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer { literal: Literal::vec1(data).reshape(&dims64)? })
    }
}

/// Device buffer (stub: host-resident literal).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Compiled executable handle. Never constructible in the stub (compile
/// errors first), but the dispatch surface must exist to typecheck.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        PjRtClient { _priv: () }
    }

    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let l = l.reshape(&[2, 2]).unwrap();
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn buffers_hold_literals() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-host");
        let b = c
            .buffer_from_host_buffer(&[7u32, 8], &[2], None)
            .unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<u32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn compile_is_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("native XLA runtime"));
    }
}
