#!/usr/bin/env bash
# Compare two BENCH_serve.json artifacts (baseline vs candidate) and
# fail on regression in the shared latency/SLO keys:
#
#   usage: scripts/bench_diff.sh BASELINE.json CANDIDATE.json [tol_pct]
#
# Keys whose flattened path contains "p95" are lower-is-better; keys
# containing "hit_rate" or "hit_ratio" are higher-is-better. A key is
# compared only when it exists in BOTH artifacts (array entries are
# matched by position — the bench emits them in deterministic order),
# so artifacts from different bench versions degrade to comparing the
# intersection instead of erroring. The default tolerance is 10%.
#
# Pure bash + awk — no jq, no python, matching the tier-1 toolchain
# assumptions (see `lazydit trace-check` for the same ethos).

set -euo pipefail

if [ $# -lt 2 ]; then
    echo "usage: $0 BASELINE.json CANDIDATE.json [tol_pct]" >&2
    exit 2
fi
base_file=$1
cand_file=$2
tol=${3:-10}
for f in "$base_file" "$cand_file"; do
    [ -f "$f" ] || { echo "bench_diff: no such file: $f" >&2; exit 2; }
done

# Flatten a JSON file to "dotted.path value" lines, numbers only. A
# character scanner, not a grammar: good enough for the single-line
# machine-written artifacts the bench emits (keys are always quoted,
# strings never contain unescaped braces).
flatten() {
    awk '
    {
        len = length($0); i = 1
        while (i <= len) {
            c = substr($0, i, 1)
            if (c == "\"") {
                s = ""; i++
                while (i <= len) {
                    c = substr($0, i, 1)
                    if (c == "\\") { s = s substr($0, i, 2); i += 2; continue }
                    if (c == "\"") break
                    s = s c; i++
                }
                i++
                if (sp > 0 && type[sp] == "o" && expect_key[sp]) {
                    key[sp] = s; expect_key[sp] = 0
                }
                continue
            }
            if (c == "{") { sp++; type[sp] = "o"; expect_key[sp] = 1; key[sp] = ""; i++; continue }
            if (c == "[") { sp++; type[sp] = "a"; idx[sp] = 0; i++; continue }
            if (c == "}" || c == "]") { sp--; i++; continue }
            if (c == ",") {
                if (type[sp] == "o") expect_key[sp] = 1; else idx[sp]++
                i++; continue
            }
            if (c == ":" || c == " " || c == "\t") { i++; continue }
            t = ""
            while (i <= len) {
                c = substr($0, i, 1)
                if (c !~ /[-+0-9.eEa-z]/) break
                t = t c; i++
            }
            if (t ~ /^[-+.0-9]/) {
                p = ""
                for (j = 1; j <= sp; j++) {
                    if (type[j] == "o") p = p "." key[j]
                    else p = p "[" idx[j] "]"
                }
                print substr(p, 2), t
            }
        }
    }' "$1"
}

base_flat=$(mktemp)
cand_flat=$(mktemp)
trap 'rm -f "$base_flat" "$cand_flat"' EXIT
flatten "$base_file" > "$base_flat"
flatten "$cand_file" > "$cand_flat"

awk -v tol="$tol" -v bf="$base_file" -v cf="$cand_file" '
    NR == FNR { base[$1] = $2; next }
    ($1 in base) {
        path = $1; b = base[path] + 0; c = $2 + 0
        dir = ""
        if (path ~ /p95/) dir = "low"
        else if (path ~ /hit_rate|hit_ratio/) dir = "high"
        if (dir == "") next
        compared++
        delta = (b > 0) ? 100.0 * (c - b) / b : 0
        bad = 0
        if (dir == "low" && b > 0 && c > b * (1 + tol / 100.0)) bad = 1
        if (dir == "high" && c < b * (1 - tol / 100.0)) bad = 1
        mark = bad ? "REGRESSED" : "ok"
        printf "  %-9s %-52s %10.4f -> %10.4f (%+.1f%%)\n", \
               mark, path, b, c, delta
        fails += bad
    }
    END {
        if (compared == 0) {
            printf "bench_diff: no shared p95/hit-rate keys between %s and %s\n", bf, cf
            exit 2
        }
        printf "bench_diff: %d shared keys, tolerance %s%%, %d regression(s)\n", \
               compared, tol, fails + 0
        exit fails > 0 ? 1 : 0
    }
' "$base_flat" "$cand_flat"
