#!/usr/bin/env bash
# Tier-1 verification: build + test + rustfmt check + doc gate + docs
# link check.
#
# Usage: scripts/tier1.sh
#   FMT_STRICT=0 scripts/tier1.sh   # demote the fmt check to advisory
#   DOC_STRICT=0 scripts/tier1.sh   # demote the doc gate to advisory
#   BENCH_SMOKE=0 scripts/tier1.sh  # skip the bench build + smoke run
#   SERVE_SMOKE=0 scripts/tier1.sh  # skip the serve telemetry smoke
#   MIGRATE_SMOKE=0 scripts/tier1.sh # skip the drain-by-migration smoke
#   CHAOS_SMOKE=0 scripts/tier1.sh  # skip the fault-injection smoke
#   DEADLINE_SMOKE=0 scripts/tier1.sh # skip the calibrate/deadline smoke
#
# The fmt check is strict by default (ROADMAP "format the tree" item);
# set FMT_STRICT=0 to demote it to advisory while iterating locally.
# Environments without the rustfmt component skip the check entirely.
# The doc gate mirrors the same pattern: `cargo doc --no-deps` with
# warnings-as-errors where rustdoc exists, skipped cleanly otherwise
# (the `pool` module additionally carries #![deny(missing_docs)], which
# the plain build already enforces).

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier1: cargo build --release"
cargo build --release

echo "== tier1: cargo test -q"
cargo test -q

echo "== tier1: cargo fmt --check (strict unless FMT_STRICT=0)"
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        if [ "${FMT_STRICT:-1}" = "1" ]; then
            echo "tier1: rustfmt check FAILED (strict mode — run 'cargo fmt --all' or set FMT_STRICT=0)"
            exit 1
        fi
        echo "tier1: rustfmt check failed (advisory — FMT_STRICT=0)"
    fi
else
    echo "tier1: rustfmt unavailable, skipping"
fi

echo "== tier1: cargo doc --no-deps (strict unless DOC_STRICT=0)"
if rustdoc --version >/dev/null 2>&1; then
    if ! RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet; then
        if [ "${DOC_STRICT:-1}" = "1" ]; then
            echo "tier1: doc gate FAILED (strict mode — fix rustdoc warnings or set DOC_STRICT=0)"
            exit 1
        fi
        echo "tier1: doc gate failed (advisory — DOC_STRICT=0)"
    fi
else
    echo "tier1: rustdoc unavailable, skipping"
fi

echo "== tier1: bench smoke (strict unless BENCH_SMOKE=0)"
# Builds every bench target (a compile gate for benches/, which plain
# `cargo build` skips), runs the step-latency bench for a tiny
# iteration count (emitting BENCH_step.json as a perf artifact), then
# the pool bench's cache-only smoke path (emitting BENCH_serve.json).
# The benches themselves assert per-step latency decreases
# monotonically with Γ, the churn inequalities, and the result-cache
# hit/warm-start/conservation properties.
# Mirrors FMT_STRICT/DOC_STRICT: skipped cleanly where cargo is absent.
if command -v cargo >/dev/null 2>&1; then
    if [ "${BENCH_SMOKE:-1}" = "1" ]; then
        cargo build --release --benches
        BENCH_SMOKE=1 cargo bench --bench step_hot_path
        # the smoke run includes the cold-churn scenario (the bench
        # itself asserts row_granular < coupled); CI additionally fails
        # if the artifact is missing the cold_churn keys, so the
        # uploaded BENCH_step.json always carries the comparison
        for key in '"cold_churn"' '"row_granular"' '"coupled"' '"warm_churn"'; do
            if ! grep -q "$key" BENCH_step.json; then
                echo "tier1: BENCH_step.json missing $key (churn sections)"
                exit 1
            fi
        done
        # the result-cache gate: the pool bench's smoke path runs only
        # the Zipf-label cache scenario (exact hits, warm starts, the
        # cache_hits conservation term — all asserted inside the bench)
        # and the artifact must carry the cache section
        BENCH_SMOKE=1 cargo bench --bench pool_scaling
        for key in '"cache"' '"hit_ratio"' '"rows_warmed"'; do
            if ! grep -q "$key" BENCH_serve.json; then
                echo "tier1: BENCH_serve.json missing $key (cache section)"
                exit 1
            fi
        done
        echo "tier1: bench smoke OK (churn + cache sections present)"
    else
        echo "tier1: bench smoke skipped (BENCH_SMOKE=0)"
    fi
else
    echo "tier1: cargo unavailable, skipping bench smoke"
fi

echo "== tier1: serve telemetry smoke (strict unless SERVE_SMOKE=0)"
# End-to-end observability gate: a synthetic 2-replica pool self-drives
# a handful of requests with the trace ring armed, writes a Chrome
# trace at shutdown, and `lazydit trace-check` re-validates the file
# structurally (pure Rust — no jq dependency). docs/OBSERVABILITY.md
# documents the trace format and the STATS/TRACE verbs this exercises.
if command -v cargo >/dev/null 2>&1; then
    if [ "${SERVE_SMOKE:-1}" = "1" ]; then
        rm -f trace_serve.json
        ./target/release/lazydit serve --synthetic --replicas 2 \
            --self-drive 6 --addr 127.0.0.1:8491 --sim-work 2000 \
            --trace-out trace_serve.json
        ./target/release/lazydit trace-check trace_serve.json
        echo "tier1: serve telemetry smoke OK (trace_serve.json validated)"
    else
        echo "tier1: serve telemetry smoke skipped (SERVE_SMOKE=0)"
    fi
else
    echo "tier1: cargo unavailable, skipping serve telemetry smoke"
fi

echo "== tier1: migration smoke (strict unless MIGRATE_SMOKE=0)"
# Drain-by-migration gate: a stealing 2-replica synthetic pool
# self-drives requests and --drain-after forces replica 0 to evict its
# mid-flight trajectories to the sibling as portable snapshots. The
# serve command itself asserts the conservation law (dispatched ==
# completed + cache_hits + shed + forfeited) and
# exits nonzero on violation; this gate additionally requires at least
# one resumed trajectory in the printed migration counters.
# docs/SERVING.md documents the snapshot/migration lifecycle.
if command -v cargo >/dev/null 2>&1; then
    if [ "${MIGRATE_SMOKE:-1}" = "1" ]; then
        # heavy per-module work keeps each trajectory mid-flight for
        # many drain-poll ticks, so the re-armed sweep reliably catches
        # a resident at a step boundary (the client is closed-loop, one
        # request in flight at a time)
        out=$(./target/release/lazydit serve --synthetic --replicas 2 \
                  --steal on --self-drive 16 --addr 127.0.0.1:8492 \
                  --sim-work 300000 --drain-after 2)
        echo "$out" | tail -n 4
        echo "$out" | grep -q 'conservation: .* ok=true' || {
            echo "tier1: migration smoke FAILED (conservation line missing)"
            exit 1
        }
        echo "$out" | grep -Eq 'migration: out=[0-9]+ in=[0-9]+ resumed=[1-9]' || {
            echo "tier1: migration smoke FAILED (no trajectory resumed)"
            exit 1
        }
        echo "tier1: migration smoke OK (drain-by-migration resumed >= 1, ledger balanced)"
    else
        echo "tier1: migration smoke skipped (MIGRATE_SMOKE=0)"
    fi
else
    echo "tier1: cargo unavailable, skipping migration smoke"
fi

echo "== tier1: chaos smoke (strict unless CHAOS_SMOKE=0)"
# Self-healing gate: a supervised 2-replica synthetic pool self-drives
# requests while replica 0 relives a deterministic panic schedule
# (panic at round 8 of every incarnation). The supervisor must respawn
# the slot into the same queue identity at least once, and the serve
# command's own conservation check (dispatched == completed +
# cache_hits + shed + forfeited, sourced from panic-proof gauges) must
# balance — it exits nonzero on violation. docs/SERVING.md documents
# the fault-plan grammar and the supervision/brownout knobs.
if command -v cargo >/dev/null 2>&1; then
    if [ "${CHAOS_SMOKE:-1}" = "1" ]; then
        out=$(./target/release/lazydit serve --synthetic --replicas 2 \
                  --steal on --supervise on --fault-plan panic@8 \
                  --self-drive 24 --addr 127.0.0.1:8493 --sim-work 20000)
        echo "$out" | tail -n 4
        echo "$out" | grep -q 'conservation: .* ok=true' || {
            echo "tier1: chaos smoke FAILED (conservation line missing)"
            exit 1
        }
        echo "$out" | grep -Eq 'supervisor: restarts=[1-9]' || {
            echo "tier1: chaos smoke FAILED (no supervised respawn)"
            exit 1
        }
        echo "tier1: chaos smoke OK (respawn >= 1, ledger balanced under panics)"
    else
        echo "tier1: chaos smoke skipped (CHAOS_SMOKE=0)"
    fi
else
    echo "tier1: cargo unavailable, skipping chaos smoke"
fi

echo "== tier1: deadline smoke (strict unless DEADLINE_SMOKE=0)"
# Calibrate-then-serve gate: `lazydit calibrate --synthetic` profiles a
# skip calendar twice (the artifact must be byte-identical — the
# determinism contract in cmd_calibrate's module doc), then a synthetic
# server loads it with --calendar, self-drives deadline-stamped
# requests, and must report deadline hits alongside a balanced ledger.
# docs/SERVING.md ("Deadlines & skip calendars") documents the flow.
if command -v cargo >/dev/null 2>&1; then
    if [ "${DEADLINE_SMOKE:-1}" = "1" ]; then
        rm -f calendar_smoke.json calendar_smoke2.json
        ./target/release/lazydit calibrate --synthetic \
            --request-steps 4 --requests 8 --sim-work 2000 \
            --out calendar_smoke.json
        ./target/release/lazydit calibrate --synthetic \
            --request-steps 4 --requests 8 --sim-work 2000 \
            --out calendar_smoke2.json
        cmp calendar_smoke.json calendar_smoke2.json || {
            echo "tier1: deadline smoke FAILED (calibrate is not deterministic)"
            exit 1
        }
        out=$(./target/release/lazydit serve --synthetic \
                  --calendar calendar_smoke.json --self-drive 6 \
                  --deadline-ms 5000 --addr 127.0.0.1:8494 --sim-work 2000)
        echo "$out" | tail -n 5
        echo "$out" | grep -q 'calendar: armed' || {
            echo "tier1: deadline smoke FAILED (calendar did not arm)"
            exit 1
        }
        echo "$out" | grep -Eq 'deadline: hits=[1-9]' || {
            echo "tier1: deadline smoke FAILED (no deadline hits)"
            exit 1
        }
        echo "$out" | grep -q 'conservation: .* ok=true' || {
            echo "tier1: deadline smoke FAILED (conservation line missing)"
            exit 1
        }
        echo "tier1: deadline smoke OK (deterministic calendar, hits >= 1, ledger balanced)"
    else
        echo "tier1: deadline smoke skipped (DEADLINE_SMOKE=0)"
    fi
else
    echo "tier1: cargo unavailable, skipping deadline smoke"
fi

echo "== tier1: docs link check (relative links in *.md)"
link_fail=0
for f in README.md docs/*.md; do
    [ -f "$f" ] || continue
    dir=$(dirname "$f")
    # markdown inline link targets: [text](target). Fenced code blocks
    # are stripped first (transcripts may contain `](` sequences), and
    # the while-read loop is quoting-safe for targets with spaces or
    # an optional "title" suffix. Process substitution (not a pipe)
    # keeps link_fail in this shell.
    while IFS= read -r link; do
        case "$link" in
            http://*|https://*|mailto:*|\#*) continue ;;
        esac
        target="${link%%#*}"       # drop the fragment
        target="${target%% \"*}"   # drop an optional "title"
        [ -z "$target" ] && continue
        if [ ! -e "$dir/$target" ] && [ ! -e "$target" ]; then
            echo "tier1: broken link in $f -> $link"
            link_fail=1
        fi
    done < <(awk '/^```/{fence=!fence; next} !fence' "$f" \
             | grep -oE '\]\([^)]+\)' | sed 's/^](//; s/)$//')
done
if [ "$link_fail" = 1 ]; then
    echo "tier1: docs link check FAILED"
    exit 1
fi

echo "== tier1: OK"
