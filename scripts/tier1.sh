#!/usr/bin/env bash
# Tier-1 verification: build + test + rustfmt check.
#
# Usage: scripts/tier1.sh
#   FMT_STRICT=0 scripts/tier1.sh   # demote the fmt check to advisory
#
# The fmt check is strict by default (ROADMAP "format the tree" item);
# set FMT_STRICT=0 to demote it to advisory while iterating locally.
# Environments without the rustfmt component skip the check entirely.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier1: cargo build --release"
cargo build --release

echo "== tier1: cargo test -q"
cargo test -q

echo "== tier1: cargo fmt --check (strict unless FMT_STRICT=0)"
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        if [ "${FMT_STRICT:-1}" = "1" ]; then
            echo "tier1: rustfmt check FAILED (strict mode — run 'cargo fmt --all' or set FMT_STRICT=0)"
            exit 1
        fi
        echo "tier1: rustfmt check failed (advisory — FMT_STRICT=0)"
    fi
else
    echo "tier1: rustfmt unavailable, skipping"
fi

echo "== tier1: OK"
