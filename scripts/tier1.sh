#!/usr/bin/env bash
# Tier-1 verification: build + test (+ advisory rustfmt check).
#
# Usage: scripts/tier1.sh
#   FMT_STRICT=1 scripts/tier1.sh   # make the fmt check fatal
#
# The fmt check is advisory by default because the seed codebase
# predates rustfmt adoption; flip FMT_STRICT=1 once the tree is
# formatted.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier1: cargo build --release"
cargo build --release

echo "== tier1: cargo test -q"
cargo test -q

echo "== tier1: cargo fmt --check (advisory unless FMT_STRICT=1)"
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        if [ "${FMT_STRICT:-0}" = "1" ]; then
            echo "tier1: rustfmt check FAILED (strict mode)"
            exit 1
        fi
        echo "tier1: rustfmt check failed (advisory — set FMT_STRICT=1 to enforce)"
    fi
else
    echo "tier1: rustfmt unavailable, skipping"
fi

echo "== tier1: OK"
