"""Training-graph tests: pretrain loss decreases, lazy loss pushes gates
toward laziness, θ stays frozen under the lazy step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, diffusion, model
from compile.configs import CONFIGS, DIFFUSION

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["nano"]
B = 8


def data(seed):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    x0 = jnp.tanh(jax.random.normal(ks[0], (B, CFG.channels, CFG.img_size,
                                            CFG.img_size)))
    y = jax.random.randint(ks[1], (B,), 0, CFG.num_classes + 1)
    t = jax.random.randint(ks[2], (B,), 0, DIFFUSION.timesteps)
    noise = jax.random.normal(ks[3], (B, CFG.channels, CFG.img_size,
                                      CFG.img_size))
    return x0, y, t, noise


class TestSchedule:
    def test_alphas_bar_monotone(self):
        ab = diffusion.alphas_bar(DIFFUSION)
        a = np.asarray(ab)
        assert a.shape == (1000,)
        assert np.all(np.diff(a) < 0)
        assert a[0] > 0.999 and a[-1] > 0.0

    def test_q_sample_interpolates(self):
        ab = diffusion.alphas_bar(DIFFUSION)
        x0 = jnp.ones((1, 1, 2, 2))
        noise = jnp.zeros((1, 1, 2, 2))
        z = diffusion.q_sample(ab, x0, jnp.array([0]), noise)
        np.testing.assert_allclose(z, np.sqrt(ab[0]) * np.ones((1, 1, 2, 2)),
                                   rtol=1e-6)


class TestPretrain:
    @pytest.mark.slow
    def test_loss_decreases(self):
        step_fn = jax.jit(diffusion.make_pretrain_step(CFG, DIFFUSION))
        theta = model.init_params(jax.random.PRNGKey(0), CFG)
        P = theta.shape[0]
        m = jnp.zeros(P)
        v = jnp.zeros(P)
        losses = []
        for i in range(30):
            x0, y, t, noise = data(i)
            theta, m, v, loss = step_fn(theta, m, v, jnp.float32(i + 1), x0,
                                        y, t, noise, jnp.float32(3e-3))
            losses.append(float(loss))
        first = np.mean(losses[:5])
        last = np.mean(losses[-5:])
        assert last < first, f"loss did not decrease: {first} -> {last}"


class TestLazyLearning:
    @pytest.fixture(scope="class")
    def theta(self):
        return model.init_params(jax.random.PRNGKey(0), CFG)

    def test_theta_frozen(self, theta):
        """The lazy step must not touch θ (it is not even an output)."""
        step_fn = jax.jit(diffusion.make_train_step(CFG, DIFFUSION))
        gamma = model.init_gates(CFG)
        G = gamma.shape[0]
        x0, y, t, noise = data(0)
        t_prev = jnp.minimum(t + 50, DIFFUSION.timesteps - 1)
        out = step_fn(theta, gamma, jnp.zeros(G), jnp.zeros(G),
                      jnp.float32(1.0), x0, y, t, t_prev, noise,
                      jnp.float32(1e-2), jnp.float32(1e-3), jnp.float32(1e-3))
        assert len(out) == 9  # gamma,m,v,dl,ll,sa,sf,fa,ff

    @pytest.mark.slow
    def test_rho_pushes_laziness(self, theta):
        """Larger ρ ⇒ mean gate value rises over training steps."""
        step_fn = jax.jit(diffusion.make_train_step(CFG, DIFFUSION))

        def run(rho, steps=25):
            gamma = model.init_gates(CFG)
            G = gamma.shape[0]
            m = jnp.zeros(G)
            v = jnp.zeros(G)
            sa = sf = 0.0
            for i in range(steps):
                x0, y, t, noise = data(100 + i)
                t_prev = jnp.minimum(t + 50, DIFFUSION.timesteps - 1)
                gamma, m, v, dl, ll, sa, sf, fa, ff = step_fn(
                    theta, gamma, m, v, jnp.float32(i + 1), x0, y, t, t_prev,
                    noise, jnp.float32(5e-2), jnp.float32(rho),
                    jnp.float32(rho))
            return float(sa), float(sf)

        sa_hi, sf_hi = run(1e-1)
        sa_lo, sf_lo = run(0.0)
        assert sa_hi > sa_lo + 0.05, f"attn laziness: {sa_lo} -> {sa_hi}"
        assert sf_hi > sf_lo + 0.05, f"ffn laziness: {sf_lo} -> {sf_hi}"
        # without penalty the diffusion loss dominates; gates should go
        # toward MORE computation (s below the 0.119 init) or stay put
        assert sa_lo <= 0.2


class TestLazyLoss:
    def test_formula(self):
        svals = jnp.array([[0.2, 0.4], [0.6, 0.8]])  # [attn; ffn], B=2
        ll = diffusion.lazy_loss(svals, jnp.float32(2.0), jnp.float32(1.0))
        # attn rows: mean(1-s)=0.7 -> *2.0 = 1.4 ; ffn: mean=0.3 -> *1 = 0.3
        np.testing.assert_allclose(float(ll), 1.7, rtol=1e-6)

    def test_zero_when_fully_lazy(self):
        svals = jnp.ones((4, 3))
        ll = diffusion.lazy_loss(svals, jnp.float32(1.0), jnp.float32(1.0))
        assert float(ll) == 0.0


class TestAdamW:
    def test_moves_toward_gradient(self):
        p = jnp.array([1.0, -1.0])
        g = jnp.array([1.0, -1.0])
        p2, m, v = diffusion.adamw_update(p, g, jnp.zeros(2), jnp.zeros(2),
                                          jnp.float32(1.0), 0.1)
        assert float(p2[0]) < 1.0 and float(p2[1]) > -1.0
        assert m.shape == (2,) and v.shape == (2,)

    def test_bias_correction_first_step(self):
        # at step 1 with zero state the update magnitude ≈ lr
        p = jnp.zeros(1)
        g = jnp.array([0.5])
        p2, _, _ = diffusion.adamw_update(p, g, jnp.zeros(1), jnp.zeros(1),
                                          jnp.float32(1.0), 0.1)
        np.testing.assert_allclose(float(-p2[0]), 0.1, rtol=1e-3)
