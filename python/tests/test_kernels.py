"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (B, N, D, H) and dtypes; assert_allclose is the
core correctness signal for the compile path (DESIGN.md §8).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


shapes = st.tuples(
    st.integers(1, 5),                       # B
    st.sampled_from([1, 4, 16, 64]),         # N
    st.sampled_from([8, 32, 96]),            # D
)


@st.composite
def attn_shapes(draw):
    b = draw(st.integers(1, 4))
    n = draw(st.sampled_from([1, 4, 16, 64]))
    d = draw(st.sampled_from([8, 32, 96]))
    h = draw(st.sampled_from([h for h in (1, 2, 4, 8) if d % h == 0]))
    return b, n, d, h


class TestModGate:
    @settings(**SETTINGS)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        B, N, D = shape
        ks = keys(seed, 8)
        args = (
            rand(ks[0], (B, N, D)),
            rand(ks[1], (B, D)),
            rand(ks[2], (D, D), 0.05),
            rand(ks[3], (D,), 0.05),
            rand(ks[4], (D, D), 0.05),
            rand(ks[5], (D,), 0.05),
            rand(ks[6], (D,), 0.2),
            jnp.float32(float(jax.random.normal(ks[7], ()))),
        )
        z1, s1 = ref.modgate(*args)
        z2, s2 = K.modgate(*args)
        np.testing.assert_allclose(z1, z2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)

    def test_gate_range(self):
        """Gate output must be a valid probability."""
        ks = keys(0, 8)
        B, N, D = 4, 16, 32
        _, s = K.modgate(
            rand(ks[0], (B, N, D)), rand(ks[1], (B, D)),
            rand(ks[2], (D, D)), rand(ks[3], (D,)),
            rand(ks[4], (D, D)), rand(ks[5], (D,)),
            rand(ks[6], (D,)), jnp.float32(0.0))
        # sigmoid may saturate to the fp32 endpoints for unscaled weights
        assert np.all(np.asarray(s) >= 0.0) and np.all(np.asarray(s) <= 1.0)

    def test_zero_gate_weight_gives_half(self):
        """w_g = 0, b_g = 0 ⇒ s = sigmoid(0) = 0.5 exactly."""
        ks = keys(1, 6)
        B, N, D = 2, 8, 16
        _, s = K.modgate(
            rand(ks[0], (B, N, D)), rand(ks[1], (B, D)),
            rand(ks[2], (D, D)), rand(ks[3], (D,)),
            rand(ks[4], (D, D)), rand(ks[5], (D,)),
            jnp.zeros((D,)), jnp.float32(0.0))
        np.testing.assert_allclose(s, 0.5, atol=1e-6)

    def test_modulation_identity(self):
        """Zero shift/scale projections ⇒ z == LayerNorm(x)."""
        ks = keys(2, 3)
        B, N, D = 2, 8, 16
        x = rand(ks[0], (B, N, D))
        z, _ = K.modgate(
            x, rand(ks[1], (B, D)),
            jnp.zeros((D, D)), jnp.zeros((D,)),
            jnp.zeros((D, D)), jnp.zeros((D,)),
            rand(ks[2], (D,)), jnp.float32(0.0))
        np.testing.assert_allclose(z, ref.layer_norm(x), rtol=1e-5, atol=1e-5)


class TestAttention:
    @settings(**SETTINGS)
    @given(attn_shapes(), st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        B, N, D, H = shape
        ks = keys(seed, 5)
        z = rand(ks[0], (B, N, D))
        wqkv = rand(ks[1], (D, 3 * D), 0.1)
        bqkv = rand(ks[2], (3 * D,), 0.1)
        wo = rand(ks[3], (D, D), 0.1)
        bo = rand(ks[4], (D,), 0.1)
        a1 = ref.attention(z, wqkv, bqkv, wo, bo, H)
        a2 = K.attention(z, wqkv, bqkv, wo, bo, H)
        np.testing.assert_allclose(a1, a2, rtol=2e-4, atol=2e-4)

    def test_permutation_equivariance(self):
        """Self-attention (no pos-emb inside) must be token-permutation
        equivariant — a structural invariant of the kernel."""
        ks = keys(3, 5)
        B, N, D, H = 1, 16, 32, 4
        z = rand(ks[0], (B, N, D))
        wqkv = rand(ks[1], (D, 3 * D), 0.1)
        bqkv = rand(ks[2], (3 * D,), 0.1)
        wo = rand(ks[3], (D, D), 0.1)
        bo = rand(ks[4], (D,), 0.1)
        perm = jax.random.permutation(ks[0], N)
        a = K.attention(z, wqkv, bqkv, wo, bo, H)
        a_p = K.attention(z[:, perm], wqkv, bqkv, wo, bo, H)
        np.testing.assert_allclose(a[:, perm], a_p, rtol=1e-4, atol=1e-4)

    def test_uniform_tokens_uniform_output(self):
        """Identical tokens ⇒ identical outputs per token."""
        ks = keys(4, 5)
        B, N, D, H = 1, 8, 16, 2
        one = rand(ks[0], (B, 1, D))
        z = jnp.tile(one, (1, N, 1))
        a = K.attention(z, rand(ks[1], (D, 3 * D), 0.1), rand(ks[2], (3 * D,), 0.1),
                        rand(ks[3], (D, D), 0.1), rand(ks[4], (D,), 0.1), H)
        np.testing.assert_allclose(a, jnp.tile(a[:, :1], (1, N, 1)), rtol=1e-4, atol=1e-5)


class TestFeedforward:
    @settings(**SETTINGS)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        B, N, D = shape
        ks = keys(seed, 5)
        z = rand(ks[0], (B, N, D))
        w1 = rand(ks[1], (D, 4 * D), 0.1)
        b1 = rand(ks[2], (4 * D,), 0.1)
        w2 = rand(ks[3], (4 * D, D), 0.1)
        b2 = rand(ks[4], (D,), 0.1)
        f1 = ref.feedforward(z, w1, b1, w2, b2)
        f2 = K.feedforward(z, w1, b1, w2, b2)
        np.testing.assert_allclose(f1, f2, rtol=2e-4, atol=2e-4)

    def test_pointwise(self):
        """FFN is pointwise: permuting tokens permutes outputs."""
        ks = keys(5, 5)
        B, N, D = 1, 16, 32
        z = rand(ks[0], (B, N, D))
        w1, b1 = rand(ks[1], (D, 4 * D), 0.1), rand(ks[2], (4 * D,), 0.1)
        w2, b2 = rand(ks[3], (4 * D, D), 0.1), rand(ks[4], (D,), 0.1)
        perm = jax.random.permutation(ks[0], N)
        f = K.feedforward(z, w1, b1, w2, b2)
        f_p = K.feedforward(z[:, perm], w1, b1, w2, b2)
        np.testing.assert_allclose(f[:, perm], f_p, rtol=1e-4, atol=1e-5)


class TestApplyOut:
    @settings(**SETTINGS)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        B, N, D = shape
        ks = keys(seed, 5)
        x = rand(ks[0], (B, N, D))
        c = rand(ks[1], (B, D))
        wa = rand(ks[2], (D, D), 0.1)
        ba = rand(ks[3], (D,), 0.1)
        f = rand(ks[4], (B, N, D))
        o1 = ref.apply_out(x, c, wa, ba, f)
        o2 = K.apply_out(x, c, wa, ba, f)
        np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)

    def test_adaln_zero_identity(self):
        """Zero alpha projection (adaLN-Zero init) ⇒ output == input."""
        ks = keys(6, 3)
        B, N, D = 2, 8, 16
        x = rand(ks[0], (B, N, D))
        o = K.apply_out(x, rand(ks[1], (B, D)), jnp.zeros((D, D)),
                        jnp.zeros((D,)), rand(ks[2], (B, N, D)))
        np.testing.assert_allclose(o, x, atol=1e-7)


class TestLazyBlend:
    def test_endpoints(self):
        """s=0 ⇒ fresh output; s=1 ⇒ cache (paper training forward)."""
        ks = keys(7, 2)
        f = rand(ks[0], (2, 8, 16))
        cache = rand(ks[1], (2, 8, 16))
        np.testing.assert_allclose(ref.lazy_blend(jnp.zeros(2), f, cache), f)
        np.testing.assert_allclose(ref.lazy_blend(jnp.ones(2), f, cache), cache)

    def test_convexity(self):
        """Blend lies between the two endpoints element-wise in norm."""
        ks = keys(8, 2)
        f = rand(ks[0], (2, 8, 16))
        cache = rand(ks[1], (2, 8, 16))
        mid = ref.lazy_blend(jnp.full(2, 0.5), f, cache)
        np.testing.assert_allclose(mid, 0.5 * f + 0.5 * cache, rtol=1e-6)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
def test_kernels_dtype_support(dtype, tol):
    """Kernels run and roughly agree with ref in bf16 too (TPU-native dtype)."""
    ks = keys(9, 8)
    B, N, D, H = 2, 16, 32, 4
    z = rand(ks[0], (B, N, D), dtype=dtype)
    wqkv = rand(ks[1], (D, 3 * D), 0.1, dtype)
    bqkv = rand(ks[2], (3 * D,), 0.1, dtype)
    wo = rand(ks[3], (D, D), 0.1, dtype)
    bo = rand(ks[4], (D,), 0.1, dtype)
    a1 = ref.attention(z, wqkv, bqkv, wo, bo, H)
    a2 = K.attention(z, wqkv, bqkv, wo, bo, H)
    np.testing.assert_allclose(np.asarray(a1, np.float32), np.asarray(a2, np.float32),
                               rtol=tol, atol=tol)
