"""AOT exporter tests: graphs lower to parseable HLO text, manifest
structure is consistent with configs.py, goldens replay exactly."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, graphs
from compile.configs import CONFIGS

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["nano"]


class TestLowering:
    def test_serving_graphs_lower_to_hlo_text(self):
        for gd in graphs.serving_graphs(CFG, 1):
            lowered = jax.jit(gd.fn).lower(*gd.example_args())
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), gd.name
            assert "ENTRY" in text, gd.name

    def test_graph_input_specs_match_fn(self):
        """Every GraphDef's example args must be accepted by its fn."""
        for gd in graphs.serving_graphs(CFG, 2):
            out = jax.eval_shape(gd.fn, *gd.example_args())
            assert out is not None, gd.name

    def test_train_graphs_have_expected_io(self):
        gds = {g.name: g for g in graphs.train_graphs(CFG, 4)}
        assert set(gds) == {"init", "pretrain_step", "train_step", "forward"}
        P = configs.spec_size(configs.param_spec(CFG))
        assert gds["pretrain_step"].inputs[0][1] == (P,)
        # train_step: theta, gamma, m, v, step, x0, y, t, t_prev, noise,
        # lr, rho_a, rho_f
        assert len(gds["train_step"].inputs) == 13


class TestManifestConsistency:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_offsets_match_configs(self, manifest):
        for name, entry in manifest["configs"].items():
            cfg = CONFIGS[name]
            expect = configs.spec_offsets(configs.param_spec(cfg))
            assert entry["params"] == expect, name
            expect_g = configs.spec_offsets(configs.gate_spec(cfg))
            assert entry["gates"] == expect_g, name

    def test_graph_files_exist(self, manifest):
        root = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts")
        for name, entry in manifest["configs"].items():
            for gname, g in entry["graphs"].items():
                path = os.path.join(root, g["file"])
                assert os.path.exists(path), f"{name}/{gname}"

    def test_goldens_replay(self, manifest):
        """Re-evaluating a graph fn on its dumped golden inputs must
        reproduce the dumped outputs bit-for-bit (same jit, same machine)."""
        root = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts")
        name = "nano"
        if name not in manifest["configs"]:
            pytest.skip("nano not exported")
        entry = manifest["configs"][name]
        bucket = entry["buckets"][0]
        gds = {g.name: g for g in graphs.serving_graphs(CFG, bucket)}
        for gname in [f"modgate_b{bucket}", f"attn_b{bucket}",
                      f"ffn_b{bucket}"]:
            gd = gds[gname]
            gdir = os.path.join(root, "goldens", name)
            ins = []
            for i in range(len(gd.inputs)):
                p = os.path.join(gdir, f"{gname}.in{i}.npy")
                if not os.path.exists(p):
                    pytest.skip("goldens not dumped")
                ins.append(jnp.asarray(np.load(p)))
            outs = jax.jit(gd.fn)(*ins)
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            for i, o in enumerate(outs):
                want = np.load(os.path.join(gdir, f"{gname}.out{i}.npy"))
                np.testing.assert_allclose(np.asarray(o), want, rtol=1e-5,
                                           atol=1e-6)


class TestFeatureNet:
    def test_deterministic_and_shaped(self):
        from compile.featurenet import make_feature_fn
        fn = make_feature_fn(8)
        k = jax.random.PRNGKey(0)
        img = jax.random.normal(k, (3, 3, 8, 8))
        f1, s1 = fn(img)
        f2, s2 = fn(img)
        assert f1.shape == (3, 64) and s1.shape == (3, 64)
        np.testing.assert_array_equal(f1, f2)

    def test_discriminates(self):
        from compile.featurenet import make_feature_fn
        fn = make_feature_fn(8)
        a = jnp.ones((1, 3, 8, 8))
        b = -jnp.ones((1, 3, 8, 8))
        fa, _ = fn(a)
        fb, _ = fn(b)
        assert float(jnp.abs(fa - fb).max()) > 1e-3
