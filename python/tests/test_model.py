"""L2 model tests: shapes, adaLN-Zero identity init, pallas/ref parity,
patchify/unpatchify inverses, flat-θ round trip, lazy blending semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.configs import CONFIGS

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["nano"]


@pytest.fixture(scope="module")
def theta():
    return model.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def gamma():
    return model.init_gates(CFG)


def batch(b=4, seed=1):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    z = jax.random.normal(k1, (b, CFG.channels, CFG.img_size, CFG.img_size))
    t = jnp.linspace(0.0, 999.0, b)
    y = jax.random.randint(k2, (b,), 0, CFG.num_classes + 1)
    return z, t, y


class TestShapes:
    def test_theta_matches_spec(self, theta):
        assert theta.shape == (configs.spec_size(configs.param_spec(CFG)),)

    def test_gamma_matches_spec(self, gamma):
        assert gamma.shape == (configs.spec_size(configs.gate_spec(CFG)),)

    def test_forward_shapes(self, theta, gamma):
        z, t, y = batch()
        eps, caches, s = model.forward(theta, gamma, CFG, z, t, y)
        assert eps.shape == z.shape
        assert len(caches) == 2 * CFG.depth
        assert caches[0].shape == (4, CFG.tokens, CFG.dim)
        assert s.shape == (2 * CFG.depth, 4)


class TestInit:
    def test_adaln_zero_identity(self, theta, gamma):
        """adaLN-Zero: at init the model output is exactly zero."""
        z, t, y = batch()
        eps, _, _ = model.forward(theta, gamma, CFG, z, t, y)
        assert float(jnp.abs(eps).max()) == 0.0

    def test_gate_init_low(self, theta, gamma):
        """Gates start non-lazy: s = sigmoid(-2) ≈ 0.119."""
        z, t, y = batch()
        _, _, s = model.forward(theta, gamma, CFG, z, t, y)
        np.testing.assert_allclose(np.asarray(s), 0.1192029, atol=1e-5)


class TestPatchify:
    def test_roundtrip(self):
        k = jax.random.PRNGKey(3)
        z = jax.random.normal(k, (2, CFG.channels, CFG.img_size, CFG.img_size))
        tokens = model.patchify(z, CFG)
        assert tokens.shape == (2, CFG.tokens, CFG.patch_dim)
        back = model.unpatchify(tokens, CFG)
        np.testing.assert_allclose(back, z, atol=1e-7)

    def test_pos_embedding_distinct(self):
        pe = model.pos_embedding(CFG)
        assert pe.shape == (CFG.tokens, CFG.dim)
        # distinct positions get distinct embeddings
        diffs = jnp.abs(pe[None] - pe[:, None]).sum(-1)
        off_diag = diffs + jnp.eye(CFG.tokens) * 1e9
        assert float(off_diag.min()) > 1e-3


class TestParity:
    def test_pallas_equals_ref_forward(self, theta, gamma):
        z, t, y = batch(b=3, seed=7)
        # perturb theta so blocks are non-trivial (alpha non-zero)
        theta2 = theta + 0.01 * jax.random.normal(jax.random.PRNGKey(9),
                                                  theta.shape)
        e1, c1, s1 = model.forward(theta2, gamma, CFG, z, t, y,
                                   use_pallas=False)
        e2, c2, s2 = model.forward(theta2, gamma, CFG, z, t, y,
                                   use_pallas=True)
        np.testing.assert_allclose(e1, e2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)
        for a, b in zip(c1, c2):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestFlat:
    def test_unflatten_flatten_roundtrip(self, theta):
        spec = configs.param_spec(CFG)
        params = model.unflatten(theta, spec)
        back = model.flatten_dict(params, spec)
        np.testing.assert_array_equal(theta, back)

    def test_offsets_contiguous(self):
        rows = configs.spec_offsets(configs.param_spec(CFG))
        off = 0
        for r in rows:
            assert r["offset"] == off
            off += r["size"]


class TestLazyBlend:
    def test_cache_passthrough_when_lazy(self, theta):
        """With gates forced fully lazy (huge bias) and caches given, the
        blended module output equals the cache."""
        spec = configs.gate_spec(CFG)
        parts = []
        for name, shape in spec:
            if name.endswith(".b"):
                parts.append(jnp.full((1,), 100.0))  # sigmoid -> 1
            else:
                parts.append(jnp.zeros(shape).reshape(-1))
        gamma_lazy = jnp.concatenate(parts)
        z, t, y = batch(b=2, seed=11)
        caches = [jnp.ones((2, CFG.tokens, CFG.dim)) * (i + 1)
                  for i in range(2 * CFG.depth)]
        _, new_caches, s = model.forward(theta, gamma_lazy, CFG, z, t, y,
                                         caches=caches)
        assert float(s.min()) > 0.999
        for nc, c in zip(new_caches, caches):
            np.testing.assert_allclose(nc, c, atol=1e-5)
