"""Graph definitions exported by aot.py — one jax function per executable.

Serving graphs call the L1 Pallas kernels so the shipped HLO contains the
fused-kernel lowering; training graphs use the ref path (autodiff).
All take/return plain arrays; parameter tensors arrive as explicit inputs
sliced by Rust from the flat θ buffer (manifest offsets).
"""

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import configs, diffusion, featurenet, model
from .configs import ModelConfig
from .kernels.apply_out import apply_out as k_apply_fn
from .kernels.attention import attention as k_attention_fn
from .kernels.feedforward import feedforward as k_feedforward_fn
from .kernels.modgate import modgate as k_modgate_fn


class GraphDef:
    """A lowerable graph: fn + example (shape, dtype) input specs."""

    def __init__(self, name: str, fn: Callable, inputs: List[Tuple[str, tuple, str]]):
        self.name = name
        self.fn = fn
        self.inputs = inputs  # (arg_name, shape, dtype-str)

    def example_args(self):
        return [jax.ShapeDtypeStruct(shape, jnp.dtype(dt))
                for _, shape, dt in self.inputs]


def _f32(name, shape):
    return (name, tuple(shape), "float32")


def serving_graphs(cfg: ModelConfig, bucket: int) -> List[GraphDef]:
    """The per-module executables for batch size `bucket`."""
    B, D, N = bucket, cfg.dim, cfg.tokens
    C, S = cfg.channels, cfg.img_size
    H = cfg.heads
    Dh = cfg.hidden
    PD = cfg.patch_dim

    def embed_fn(z, t, y, w_patch, b_patch, tw1, tb1, tw2, tb2, y_table):
        params = {
            "embed.patch.w": w_patch, "embed.patch.b": b_patch,
            "embed.t.w1": tw1, "embed.t.b1": tb1,
            "embed.t.w2": tw2, "embed.t.b2": tb2,
            "embed.y.table": y_table,
        }
        return model.embed(params, cfg, z, t, y)

    def modgate_fn(x, c, w_sh, b_sh, w_sc, b_sc, w_g, b_g):
        return k_modgate_fn(x, c, w_sh, b_sh, w_sc, b_sc, w_g,
                                 b_g.reshape(()))

    def attn_fn(z, w_qkv, b_qkv, w_o, b_o):
        return (k_attention_fn(z, w_qkv, b_qkv, w_o, b_o, H),)

    def ffn_fn(z, w1, b1, w2, b2):
        return (k_feedforward_fn(z, w1, b1, w2, b2),)

    def apply_fn(x, c, w_al, b_al, f):
        return (k_apply_fn(x, c, w_al, b_al, f),)

    def final_fn(x, c, w_sh, b_sh, w_sc, b_sc, w_out, b_out):
        params = {
            "final.w_shift": w_sh, "final.b_shift": b_sh,
            "final.w_scale": w_sc, "final.b_scale": b_sc,
            "final.w_out": w_out, "final.b_out": b_out,
        }
        return (model.final_layer(params, cfg, x, c),)

    feature_raw = featurenet.make_feature_fn(cfg.img_size, cfg.channels)

    def feature_fn(img):
        return feature_raw(img)

    return [
        GraphDef(f"embed_b{B}", embed_fn, [
            _f32("z", (B, C, S, S)), _f32("t", (B,)),
            ("y", (B,), "int32"),
            _f32("w_patch", (PD, D)), _f32("b_patch", (D,)),
            _f32("tw1", (cfg.freq_dim, D)), _f32("tb1", (D,)),
            _f32("tw2", (D, D)), _f32("tb2", (D,)),
            _f32("y_table", (cfg.num_classes + 1, D)),
        ]),
        GraphDef(f"modgate_b{B}", modgate_fn, [
            _f32("x", (B, N, D)), _f32("c", (B, D)),
            _f32("w_sh", (D, D)), _f32("b_sh", (D,)),
            _f32("w_sc", (D, D)), _f32("b_sc", (D,)),
            _f32("w_g", (D,)), _f32("b_g", (1,)),
        ]),
        GraphDef(f"attn_b{B}", attn_fn, [
            _f32("z", (B, N, D)),
            _f32("w_qkv", (D, 3 * D)), _f32("b_qkv", (3 * D,)),
            _f32("w_o", (D, D)), _f32("b_o", (D,)),
        ]),
        GraphDef(f"ffn_b{B}", ffn_fn, [
            _f32("z", (B, N, D)),
            _f32("w1", (D, Dh)), _f32("b1", (Dh,)),
            _f32("w2", (Dh, D)), _f32("b2", (D,)),
        ]),
        GraphDef(f"apply_b{B}", apply_fn, [
            _f32("x", (B, N, D)), _f32("c", (B, D)),
            _f32("w_al", (D, D)), _f32("b_al", (D,)),
            _f32("f", (B, N, D)),
        ]),
        GraphDef(f"final_b{B}", final_fn, [
            _f32("x", (B, N, D)), _f32("c", (B, D)),
            _f32("w_sh", (D, D)), _f32("b_sh", (D,)),
            _f32("w_sc", (D, D)), _f32("b_sc", (D,)),
            _f32("w_out", (D, PD)), _f32("b_out", (PD,)),
        ]),
        GraphDef(f"feature_b{B}", feature_fn, [
            _f32("img", (B, C, S, S)),
        ]),
    ]


def train_graphs(cfg: ModelConfig, train_batch: int) -> List[GraphDef]:
    """init / pretrain_step / train_step at the fixed training batch."""
    B = train_batch
    C, S = cfg.channels, cfg.img_size
    P = configs.spec_size(configs.param_spec(cfg))
    G = configs.spec_size(configs.gate_spec(cfg))
    dc = configs.DIFFUSION

    def init_fn(key):
        return (model.init_params(key, cfg),)

    pre = diffusion.make_pretrain_step(cfg, dc)

    def pretrain_fn(theta, m, v, step, x0, y, t, noise, lr):
        return pre(theta, m, v, step, x0, y, t, noise, lr)

    lazy = diffusion.make_train_step(cfg, dc)

    def train_fn(theta, gamma, m, v, step, x0, y, t, t_prev, noise, lr,
                 rho_a, rho_f):
        return lazy(theta, gamma, m, v, step, x0, y, t, t_prev, noise, lr,
                    rho_a, rho_f)

    # A gate-free full forward used for parity/golden checks from Rust:
    # one whole denoise-model evaluation in a single graph.
    def forward_fn(theta, z, t, y):
        eps, _, _ = model.forward(theta, model.init_gates(cfg), cfg, z, t, y,
                                  caches=None, use_pallas=False)
        return (eps,)

    batch = [
        _f32("x0", (B, C, S, S)), ("y", (B,), "int32"), ("t", (B,), "int32"),
    ]
    return [
        GraphDef("init", init_fn, [("key", (2,), "uint32")]),
        GraphDef("pretrain_step", pretrain_fn, [
            _f32("theta", (P,)), _f32("m", (P,)), _f32("v", (P,)),
            _f32("step", ()), *batch, _f32("noise", (B, C, S, S)),
            _f32("lr", ()),
        ]),
        GraphDef("train_step", train_fn, [
            _f32("theta", (P,)), _f32("gamma", (G,)),
            _f32("m", (G,)), _f32("v", (G,)), _f32("step", ()),
            *batch, ("t_prev", (B,), "int32"),
            _f32("noise", (B, C, S, S)), _f32("lr", ()),
            _f32("rho_a", ()), _f32("rho_f", ()),
        ]),
        GraphDef("forward", forward_fn, [
            _f32("theta", (P,)), _f32("z", (B, C, S, S)), _f32("t", (B,)),
            ("y", (B,), "int32"),
        ]),
    ]
