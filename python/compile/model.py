"""L2: the DiT model (adaLN-Zero diffusion transformer) with lazy gates.

Two parallel implementations of the block math:
  * `use_pallas=True`  — calls the L1 Pallas kernels; used for the serving
    per-module exports so the kernels lower into the shipped HLO.
  * `use_pallas=False` — calls kernels.ref (pure jnp); used for the training
    graphs (autodiff through pallas_call interpret mode is not supported for
    all primitives) and as the oracle. Equality of the two paths is enforced
    by python/tests/test_model.py.

Parameters travel as ONE flat f32 vector θ (base) plus one flat vector γ
(gates); `unflatten` slices them into a dict following configs.param_spec.
This keeps the Rust interface to a single contiguous buffer + offset table.
"""

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import configs
from .configs import ModelConfig
from .kernels import ref
from .kernels.modgate import modgate as k_modgate
from .kernels.attention import attention as k_attention
from .kernels.feedforward import feedforward as k_feedforward
from .kernels.apply_out import apply_out as k_apply


# ---------------------------------------------------------------- flat θ

def unflatten(theta: jnp.ndarray, spec) -> Dict[str, jnp.ndarray]:
    """Slice the flat parameter vector into named tensors (static slices)."""
    out, off = {}, 0
    for name, shape in spec:
        n = 1
        for d in shape:
            n *= d
        out[name] = jax.lax.slice(theta, (off,), (off + n,)).reshape(shape)
        off += n
    return out


def flatten_dict(params: Dict[str, jnp.ndarray], spec) -> jnp.ndarray:
    return jnp.concatenate([params[name].reshape(-1) for name, _ in spec])


# ---------------------------------------------------------------- init

def init_params(key: jax.Array, cfg: ModelConfig) -> jnp.ndarray:
    """DiT initialisation, returned as the flat θ vector.

    Follows the DiT paper: trunc-normal-ish (plain normal here) linear
    init scaled by fan-in; adaLN-Zero — all alpha (output-gate) projections
    and the final linear are ZERO so every block starts as identity.
    """
    spec = configs.param_spec(cfg)
    params: Dict[str, jnp.ndarray] = {}
    keys = jax.random.split(key, len(spec))
    for (name, shape), k in zip(spec, keys):
        if name.endswith(".b") or ".b_" in name or name.endswith(("b1", "b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif "w_alpha" in name or name == "final.w_out":
            params[name] = jnp.zeros(shape, jnp.float32)  # adaLN-Zero
        elif name == "embed.y.table":
            params[name] = 0.02 * jax.random.normal(k, shape)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params[name] = std * jax.random.normal(k, shape)
    return flatten_dict(params, spec)


def init_gates(cfg: ModelConfig, bias: float = -2.0) -> jnp.ndarray:
    """γ init: w=0, b=bias ⇒ s = sigmoid(bias) ≈ 0.12 — start non-lazy."""
    spec = configs.gate_spec(cfg)
    parts = []
    for name, shape in spec:
        if name.endswith(".b"):
            parts.append(jnp.full((1,), bias, jnp.float32))
        else:
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------- embeds

def patchify(z: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """[B,C,H,W] -> [B,N,p*p*C] in row-major patch order."""
    B = z.shape[0]
    p, s = cfg.patch, cfg.img_size // cfg.patch
    z = z.reshape(B, cfg.channels, s, p, s, p)
    z = z.transpose(0, 2, 4, 1, 3, 5)  # B, sy, sx, C, py, px
    return z.reshape(B, s * s, cfg.patch_dim)


def unpatchify(tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """[B,N,p*p*C] -> [B,C,H,W] (inverse of patchify)."""
    B = tokens.shape[0]
    p, s = cfg.patch, cfg.img_size // cfg.patch
    z = tokens.reshape(B, s, s, cfg.channels, p, p)
    z = z.transpose(0, 3, 1, 4, 2, 5)
    return z.reshape(B, cfg.channels, cfg.img_size, cfg.img_size)


def pos_embedding(cfg: ModelConfig) -> jnp.ndarray:
    """Fixed 2D sin-cos positional embedding [N, D] (DiT convention)."""
    s = cfg.img_size // cfg.patch
    D = cfg.dim
    d_half = D // 2

    def axis_emb(pos):  # pos: [s] -> [s, d_half]
        omega = jnp.arange(d_half // 2, dtype=jnp.float32) / max(d_half // 2, 1)
        omega = 1.0 / (10000.0 ** omega)
        out = pos[:, None] * omega[None, :]
        return jnp.concatenate([jnp.sin(out), jnp.cos(out)], axis=1)

    grid = jnp.arange(s, dtype=jnp.float32)
    ey = axis_emb(grid)  # [s, d_half]
    ex = axis_emb(grid)
    full = jnp.concatenate(
        [
            jnp.repeat(ey[:, None, :], s, axis=1),   # varies along rows
            jnp.repeat(ex[None, :, :], s, axis=0),   # varies along cols
        ],
        axis=-1,
    )  # [s, s, D]
    return full.reshape(s * s, D)


def timestep_embedding(t: jnp.ndarray, freq_dim: int) -> jnp.ndarray:
    """Sinusoidal embedding of (float) timesteps t: [B] -> [B, freq_dim]."""
    half = freq_dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def embed(params: Dict[str, jnp.ndarray], cfg: ModelConfig,
          z: jnp.ndarray, t: jnp.ndarray, y: jnp.ndarray
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Patchify + pos-emb + conditioning vector c = SiLU(t_emb + y_emb).

    z: [B,C,H,W]; t: [B] float timesteps; y: [B] int labels (num_classes
    is the CFG null label). Returns (x [B,N,D], c [B,D]).
    """
    x = patchify(z, cfg) @ params["embed.patch.w"] + params["embed.patch.b"]
    x = x + pos_embedding(cfg)[None]
    te = timestep_embedding(t, cfg.freq_dim)
    te = jax.nn.silu(te @ params["embed.t.w1"] + params["embed.t.b1"])
    te = te @ params["embed.t.w2"] + params["embed.t.b2"]
    ye = params["embed.y.table"][y]
    c = jax.nn.silu(te + ye)
    return x, c


def final_layer(params: Dict[str, jnp.ndarray], cfg: ModelConfig,
                x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Final adaLN + linear + unpatchify -> eps [B,C,H,W]."""
    shift = c @ params["final.w_shift"] + params["final.b_shift"]
    scale = c @ params["final.w_scale"] + params["final.b_scale"]
    zf = ref.modulate(ref.layer_norm(x), shift, scale)
    out = zf @ params["final.w_out"] + params["final.b_out"]
    return unpatchify(out, cfg)


# ---------------------------------------------------------------- blocks

def _block_params(params, l: int, mod: str):
    p = lambda suffix: params[f"block{l}.{mod}.{suffix}"]
    return p


def block_module(params: Dict[str, jnp.ndarray], gates: Dict[str, jnp.ndarray],
                 cfg: ModelConfig, l: int, mod: str,
                 x: jnp.ndarray, c: jnp.ndarray,
                 cache: Optional[jnp.ndarray], use_pallas: bool
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One lazy module (MHSA or FFN) of block l, training-style blending.

    Returns (x_out, f_blend, s):
      f_blend — the value to cache for step t (paper caches Y_{l,t});
      s       — the gate value [B].
    If cache is None the gate is still evaluated but no blending happens
    (first step of a trajectory, or cache-free forward).
    """
    p = _block_params(params, l, mod)
    mg = k_modgate if use_pallas else ref.modgate
    at = (lambda z: (k_attention if use_pallas else ref.attention)(
        z, p("w_qkv"), p("b_qkv"), p("w_o"), p("b_o"), cfg.heads))
    ff = (lambda z: (k_feedforward if use_pallas else ref.feedforward)(
        z, p("w1"), p("b1"), p("w2"), p("b2")))
    ap = k_apply if use_pallas else ref.apply_out

    z, s = mg(x, c, p("w_shift"), p("b_shift"), p("w_scale"), p("b_scale"),
              gates[f"gate{l}.{mod}.w"], gates[f"gate{l}.{mod}.b"])
    f = at(z) if mod == "attn" else ff(z)
    f_blend = f if cache is None else ref.lazy_blend(s, f, cache)
    x_out = ap(x, c, p("w_alpha"), p("b_alpha"), f_blend)
    return x_out, f_blend, s


def forward(theta: jnp.ndarray, gamma: jnp.ndarray, cfg: ModelConfig,
            z: jnp.ndarray, t: jnp.ndarray, y: jnp.ndarray,
            caches: Optional[List[jnp.ndarray]] = None,
            use_pallas: bool = False,
            ) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray]:
    """Full DiT forward with training-style lazy blending.

    caches: list of 2L tensors [B,N,D] ordered (l0.attn, l0.ffn, l1.attn, …)
    or None. Returns (eps [B,C,H,W], new_caches (same order), s [2L, B]).
    """
    params = unflatten(theta, configs.param_spec(cfg))
    gates = unflatten(gamma, configs.gate_spec(cfg))
    x, c = embed(params, cfg, z, t, y)
    new_caches, svals = [], []
    for l in range(cfg.depth):
        for mi, mod in enumerate(("attn", "ffn")):
            cache = caches[2 * l + mi] if caches is not None else None
            x, f, s = block_module(params, gates, cfg, l, mod, x, c, cache,
                                   use_pallas)
            new_caches.append(f)
            svals.append(s)
    eps = final_layer(params, cfg, x, c)
    return eps, new_caches, jnp.stack(svals)
