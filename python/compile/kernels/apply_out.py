"""Fused adaLN-Zero output-gate + residual Pallas kernel (L1).

Computes  x + alpha(c) ∘ f  in one VMEM pass: the alpha projection is a
D×D matvec on the conditioning vector, then the residual add and the
per-channel scale are fused element-wise over the [N, D] tile. This is the
second fusion the paper's mobile framework performs around each module
(DESIGN.md §3); crucially it is also the *only* compute that runs for a
module on a skip step (the cached f is re-applied with the *current*
step's alpha, as prescribed in paper Sec. 3.3: "the input scale, input
shift, output scale, and residual connections remain unchanged").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apply_kernel(x_ref, c_ref, wa_ref, ba_ref, f_ref, o_ref):
    """One batch element: x,f [N,D], c [D] -> o = x + (c·Wa + ba) ∘ f."""
    alpha = c_ref[...] @ wa_ref[...] + ba_ref[...]
    o_ref[...] = x_ref[...] + alpha[None, :] * f_ref[...]


@functools.partial(jax.jit, static_argnames=())
def apply_out(x, c, w_alpha, b_alpha, f):
    """Pallas version of ref.apply_out; identical signature/semantics."""
    B, N, D = x.shape
    return pl.pallas_call(
        _apply_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, D), lambda b: (b, 0)),
            pl.BlockSpec((D, D), lambda b: (0, 0)),
            pl.BlockSpec((D,), lambda b: (0,)),
            pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, D), x.dtype),
        interpret=True,
    )(x, c, w_alpha, b_alpha, f)
