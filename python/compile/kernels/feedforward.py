"""Pointwise feedforward (GELU MLP) Pallas kernel (L1).

Grid = (B,): one program per batch element keeps the [N, D] tile and both
weight tiles in VMEM and feeds the MXU two back-to-back matmuls with the
GELU fused between them on the VPU — the TPU rendition of the paper's
fused mobile MLP (DESIGN.md §3). Working set N·D + D·4D + 4D·D + N·4D
floats ≤ ~1.3 MB for the largest config (`l7b-a`, D=192, N=16), far under
VMEM capacity, so no K-dim tiling is required.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT_2_OVER_PI = 0.7978845608028654


def _gelu_tanh(x):
    """tanh-approx GELU (matches jax.nn.gelu(approximate=True))."""
    return 0.5 * x * (1.0 + jnp.tanh(_SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)))


def _ffn_kernel(z_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One batch element: z [N,D] -> o [N,D] via GELU MLP with hidden 4D."""
    z = z_ref[...]
    h = _gelu_tanh(z @ w1_ref[...] + b1_ref[...][None, :])
    o_ref[...] = h @ w2_ref[...] + b2_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=())
def feedforward(z, w1, b1, w2, b2):
    """Pallas version of ref.feedforward; identical signature/semantics."""
    B, N, D = z.shape
    Dh = w1.shape[1]
    return pl.pallas_call(
        _ffn_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((D, Dh), lambda b: (0, 0)),
            pl.BlockSpec((Dh,), lambda b: (0,)),
            pl.BlockSpec((Dh, D), lambda b: (0, 0)),
            pl.BlockSpec((D,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, D), z.dtype),
        interpret=True,
    )(z, w1, b1, w2, b2)
