"""L1 Pallas kernels for LazyDiT (all interpret=True; see DESIGN.md §3).

Public surface:
  modgate.modgate        fused LN + adaLN modulate + lazy gate
  attention.attention    multi-head self-attention
  feedforward.feedforward  GELU MLP
  apply_out.apply_out    fused adaLN-Zero output gate + residual
  ref                    pure-jnp oracle for all of the above
"""

from . import ref  # noqa: F401
from .modgate import modgate  # noqa: F401
from .attention import attention  # noqa: F401
from .feedforward import feedforward  # noqa: F401
from .apply_out import apply_out  # noqa: F401
