"""Fused LN + adaLN-modulate + lazy-gate Pallas kernel (L1).

This is the paper's lazy-learning layer fused with the modulation that
precedes each MHSA / Feedforward module.  On a real TPU the fusion keeps
the [N, D] tile resident in VMEM for a single pass (LayerNorm statistics,
modulation, and the D→1 gate matvec), replacing four separate HBM
round-trips (LN read/write, modulate read/write) with one read + one write.
Here it is lowered with interpret=True so the same HLO runs on CPU PJRT.

Grid: one program per batch element.  Per-program working set
(N·D + 2·D·D + O(D) floats) stays ≤ ~0.5 MB for every config in
DESIGN.md §4, well inside a TPU core's ~16 MB VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _modgate_kernel(x_ref, c_ref, wsh_ref, bsh_ref, wsc_ref, bsc_ref,
                    wg_ref, bg_ref, z_ref, s_ref):
    """One batch element: x_ref [N,D], c_ref [D] -> z_ref [N,D], s_ref [1]."""
    x = x_ref[...]
    c = c_ref[...]
    # LayerNorm over D (fp32 statistics).
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + ref.LN_EPS)
    # adaLN shift/scale from the conditioning vector (two D×D matvecs).
    shift = c @ wsh_ref[...] + bsh_ref[...]
    scale = c @ wsc_ref[...] + bsc_ref[...]
    z = xn * (1.0 + scale)[None, :] + shift[None, :]
    z_ref[...] = z
    # Lazy gate: sigmoid(mean_N(z · w_g) + b_g)  (paper Sec 3.3, D_out = 1).
    logits = z @ wg_ref[...]  # [N]
    s_ref[...] = jax.nn.sigmoid(jnp.mean(logits)[None] + bg_ref[...])


@functools.partial(jax.jit, static_argnames=())
def modgate(x, c, w_shift, b_shift, w_scale, b_scale, w_gate, b_gate):
    """Pallas-fused version of ref.modgate; identical signature/semantics."""
    B, N, D = x.shape
    b_gate1 = jnp.reshape(b_gate, (1,))
    z, s = pl.pallas_call(
        _modgate_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, D), lambda b: (b, 0)),
            pl.BlockSpec((D, D), lambda b: (0, 0)),
            pl.BlockSpec((D,), lambda b: (0,)),
            pl.BlockSpec((D, D), lambda b: (0, 0)),
            pl.BlockSpec((D,), lambda b: (0,)),
            pl.BlockSpec((D,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, D), x.dtype),
            jax.ShapeDtypeStruct((B,), x.dtype),
        ],
        interpret=True,
    )(x, c, w_shift, b_shift, w_scale, b_scale, w_gate, b_gate1)
    return z, s
