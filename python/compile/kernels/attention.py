"""Multi-head self-attention Pallas kernel (L1).

TPU mapping of the paper's MHSA hot path (the paper's mobile/OpenCL fusion
is re-thought for the MXU, per DESIGN.md §3):

  * grid = (B, H): one program per (batch element, head) — the analogue of
    the paper's per-threadblock tiling, expressed as a BlockSpec HBM→VMEM
    schedule instead of shared-memory staging.
  * Q/K/V are produced inside the program from the head's [D, 3·dh] weight
    slice, so the [N, D] input tile is read from HBM exactly once per head.
  * QKᵀ and AV are [N, dh] × [dh, N] / [N, N] × [N, dh] MXU matmuls; with
    N ≤ 256, dh ≤ 32 everything (≈ N·D + 3·D·dh + N² floats ≤ ~0.6 MB)
    stays VMEM-resident; softmax is a single fused VPU pass — no streaming
    needed at these shapes.
  * The output projection is a separate kernel (`_proj_kernel`) because it
    reduces across heads.

interpret=True lowers this to plain HLO so the CPU PJRT client can run it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_head_kernel(z_ref, wqkv_ref, bqkv_ref, o_ref):
    """One (batch, head): z [N,D], wqkv [D,3dh], bqkv [3dh] -> o [N,dh]."""
    z = z_ref[...]
    w = wqkv_ref[...]
    b = bqkv_ref[...]
    dh = w.shape[1] // 3
    q = z @ w[:, 0 * dh:1 * dh] + b[0 * dh:1 * dh][None, :]
    k = z @ w[:, 1 * dh:2 * dh] + b[1 * dh:2 * dh][None, :]
    v = z @ w[:, 2 * dh:3 * dh] + b[2 * dh:3 * dh][None, :]
    logits = (q @ k.T) * (1.0 / jnp.sqrt(jnp.float32(dh))).astype(z.dtype)
    # Numerically-stable softmax, fused in-register on TPU.
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    attn = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = attn @ v


def _proj_kernel(h_ref, wo_ref, bo_ref, o_ref):
    """One batch element: h [N,D] (concat heads), wo [D,D] -> o [N,D]."""
    o_ref[...] = h_ref[...] @ wo_ref[...] + bo_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("num_heads",))
def attention(z, w_qkv, b_qkv, w_o, b_o, num_heads: int):
    """Pallas version of ref.attention; identical signature/semantics.

    w_qkv is stored [D, 3D] with layout [Wq | Wk | Wv]; each head h owns
    columns h·dh:(h+1)·dh inside each of the three D-wide groups. We
    re-pack to [H, D, 3·dh] so one BlockSpec slice feeds each program.
    """
    B, N, D = z.shape
    dh = D // num_heads
    # [D, 3, H, dh] -> [H, D, 3*dh] per-head packed weights.
    wq, wk, wv = jnp.split(w_qkv, 3, axis=1)
    bq, bk, bv = jnp.split(b_qkv, 3, axis=0)

    def pack_w(w):  # [D, D] -> [H, D, dh]
        return w.reshape(D, num_heads, dh).transpose(1, 0, 2)

    def pack_b(b):  # [D] -> [H, dh]
        return b.reshape(num_heads, dh)

    w_heads = jnp.concatenate([pack_w(wq), pack_w(wk), pack_w(wv)], axis=-1)
    b_heads = jnp.concatenate([pack_b(bq), pack_b(bk), pack_b(bv)], axis=-1)

    heads = pl.pallas_call(
        _attn_head_kernel,
        grid=(B, num_heads),
        in_specs=[
            pl.BlockSpec((None, N, D), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((None, D, 3 * dh), lambda b, h: (h, 0, 0)),
            pl.BlockSpec((None, 3 * dh), lambda b, h: (h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, N, dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, num_heads, N, dh), z.dtype),
        interpret=True,
    )(z, w_heads, b_heads)

    h_cat = heads.transpose(0, 2, 1, 3).reshape(B, N, D)
    out = pl.pallas_call(
        _proj_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((D, D), lambda b: (0, 0)),
            pl.BlockSpec((D,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((None, N, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, D), z.dtype),
        interpret=True,
    )(h_cat, w_o, b_o)
    return out
