"""Pure-jnp reference oracle for every Pallas kernel in this package.

These functions define the *semantics* each kernel must reproduce; pytest
(`python/tests/test_kernels.py`) sweeps shapes/dtypes with hypothesis and
asserts allclose between the Pallas kernels (interpret=True) and these.

Shape conventions (match DESIGN.md §2):
  x, z, f : [B, N, D]   token hidden states
  c       : [B, D]      conditioning vector SiLU(t_emb + y_emb)
  s       : [B]         lazy-gate similarity in (0, 1)
"""

import jax
import jax.numpy as jnp

LN_EPS = 1e-6


def layer_norm(x: jnp.ndarray, eps: float = LN_EPS) -> jnp.ndarray:
    """LayerNorm over the last axis, no learnable affine (DiT adaLN style)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def modulate(x_ln: jnp.ndarray, shift: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """adaLN modulation: broadcast per-batch shift/scale over tokens.

    x_ln: [B,N,D]; shift, scale: [B,D].
    """
    return x_ln * (1.0 + scale[:, None, :]) + shift[:, None, :]


def modgate(x, c, w_shift, b_shift, w_scale, b_scale, w_gate, b_gate):
    """Fused LN + adaLN-modulate + lazy gate (paper Sec. 3.3, training forward).

    Args:
      x: [B,N,D] block input.
      c: [B,D] conditioning vector.
      w_shift, w_scale: [D,D]; b_shift, b_scale: [D]  (adaLN projections).
      w_gate: [D]; b_gate: [] — the lazy-learning linear layer (D_out = 1).
    Returns:
      z: [B,N,D] modulated input Z_{l,t};
      s: [B] gate value  sigmoid(mean_N(Z · w_g) + b_g).
    """
    shift = c @ w_shift + b_shift
    scale = c @ w_scale + b_scale
    z = modulate(layer_norm(x), shift, scale)
    logits = jnp.einsum("bnd,d->bn", z, w_gate)
    s = jax.nn.sigmoid(jnp.mean(logits, axis=-1) + b_gate)
    return z, s


def attention(z, w_qkv, b_qkv, w_o, b_o, num_heads: int):
    """Multi-head self-attention over modulated input z: [B,N,D]."""
    B, N, D = z.shape
    dh = D // num_heads
    qkv = z @ w_qkv + b_qkv  # [B,N,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def split_heads(a):  # [B,N,D] -> [B,H,N,dh]
        return a.reshape(B, N, num_heads, dh).transpose(0, 2, 1, 3)

    q, k, v = split_heads(q), split_heads(k), split_heads(v)
    logits = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(dh).astype(z.dtype)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhnm,bhmd->bhnd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, N, D)
    return out @ w_o + b_o


def feedforward(z, w1, b1, w2, b2):
    """Pointwise MLP with tanh-approx GELU: [B,N,D] -> [B,N,D]."""
    h = jax.nn.gelu(z @ w1 + b1, approximate=True)
    return h @ w2 + b2


def apply_out(x, c, w_alpha, b_alpha, f):
    """adaLN-Zero output gate + residual:  x + alpha(c) ∘ f.

    w_alpha: [D,D], b_alpha: [D]. alpha is zero at init (adaLN-Zero),
    achieved by zero-initialising w_alpha/b_alpha in the model init.
    """
    alpha = c @ w_alpha + b_alpha  # [B,D]
    return x + alpha[:, None, :] * f


def lazy_blend(s, f, cache):
    """Training-time blend (paper's training forward):
    diag(1-s)·F(Z) + diag(s)·Y_prev.  s: [B]; f, cache: [B,N,D]."""
    w = s[:, None, None]
    return (1.0 - w) * f + w * cache
