"""L2: diffusion process, losses, and the two AOT training step functions.

`pretrain_step` — full AdamW step on the frozen-to-be base model θ.
`train_step`    — the paper's LAZY LEARNING step: θ frozen, gates γ trained
                  with diffusion loss + lazy loss (paper Eq. 5), caches
                  produced by a gate-free forward at the *previous*
                  (noisier) timestep, exactly mirroring inference where
                  Y_{l,t-1} comes from the preceding sampling step.

Both are pure jax functions over flat parameter vectors so Rust drives the
whole training loop through PJRT with single-buffer parameter I/O.
"""

from typing import Tuple

import jax
import jax.numpy as jnp

from . import configs, model
from .configs import DiffusionConfig, ModelConfig


# ---------------------------------------------------------------- schedule

def betas(dc: DiffusionConfig) -> jnp.ndarray:
    """Linear beta schedule (DiT/ADM convention)."""
    return jnp.linspace(dc.beta_start, dc.beta_end, dc.timesteps,
                        dtype=jnp.float32)


def alphas_bar(dc: DiffusionConfig) -> jnp.ndarray:
    return jnp.cumprod(1.0 - betas(dc))


def q_sample(ab: jnp.ndarray, x0: jnp.ndarray, t: jnp.ndarray,
             noise: jnp.ndarray) -> jnp.ndarray:
    """Forward process: z_t = sqrt(ᾱ_t)·x0 + sqrt(1-ᾱ_t)·ε.  t: int [B]."""
    a = ab[t][:, None, None, None]
    return jnp.sqrt(a) * x0 + jnp.sqrt(1.0 - a) * noise


# ---------------------------------------------------------------- losses

def diffusion_loss(eps_pred: jnp.ndarray, noise: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.square(eps_pred - noise))


def lazy_loss(svals: jnp.ndarray, rho_attn: jnp.ndarray,
              rho_ffn: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. (5): ρ·(1/B)·Σ_l Σ_b (1 − s). svals: [2L, B], rows
    alternating (attn, ffn) per layer — minimising pushes s ↑ (lazier)."""
    s_attn = svals[0::2]
    s_ffn = svals[1::2]
    la = jnp.sum(jnp.mean(1.0 - s_attn, axis=1))
    lf = jnp.sum(jnp.mean(1.0 - s_ffn, axis=1))
    return rho_attn * la + rho_ffn * lf


# ---------------------------------------------------------------- AdamW

def adamw_update(p, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                 weight_decay=0.0):
    """One AdamW step over flat vectors. step is 1-based (f32 scalar)."""
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * jnp.square(g)
    mhat = m / (1.0 - beta1 ** step)
    vhat = v / (1.0 - beta2 ** step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
    return p, m, v


# ---------------------------------------------------------------- steps

def make_pretrain_step(cfg: ModelConfig, dc: DiffusionConfig):
    """Returns f(θ, m, v, step, x0, y, t, noise, lr) → (θ', m', v', loss).

    y already contains null labels where the host applied CFG dropout.
    t: int32 [B]; noise: ε ~ N(0,1) sampled by the host.
    """
    ab = alphas_bar(dc)
    gamma0 = model.init_gates(cfg)  # unused gates (blend-free fwd)

    def loss_fn(theta, x0, y, t, noise):
        z_t = q_sample(ab, x0, t, noise)
        eps, _, _ = model.forward(theta, gamma0, cfg, z_t,
                                  t.astype(jnp.float32), y, caches=None,
                                  use_pallas=False)
        return diffusion_loss(eps, noise)

    def step_fn(theta, m, v, step, x0, y, t, noise, lr):
        loss, g = jax.value_and_grad(loss_fn)(theta, x0, y, t, noise)
        theta, m, v = adamw_update(theta, g, m, v, step, lr)
        return theta, m, v, loss

    return step_fn


def make_train_step(cfg: ModelConfig, dc: DiffusionConfig):
    """The lazy-learning step (paper Sec. 3.3 'Training Forward'/'Backward
    Loss').

    Signature: f(θ, γ, m, v, step, x0, y, t, t_prev, noise, lr, ρa, ρf)
             → (γ', m', v', dloss, lazyloss, s̄_attn, s̄_ffn, frac_attn,
                frac_ffn)

    frac_* are the train-time skip fractions mean(s > 0.5) — the signal the
    Rust ρ-controller steers toward a target lazy ratio (paper "Penalty
    Regulation" done adaptively instead of by manual sweep).

    θ is FROZEN (no gradient); caches come from a gate-free forward at
    t_prev > t (the noisier preceding sampling step), then the gated
    forward at t blends module outputs with those caches and both losses
    backprop into γ only.
    """
    ab = alphas_bar(dc)

    def loss_fn(gamma, theta, x0, y, t, t_prev, noise):
        z_prev = q_sample(ab, x0, t_prev, noise)
        _, caches, _ = model.forward(theta, model_init_gates_const(cfg), cfg,
                                     z_prev, t_prev.astype(jnp.float32), y,
                                     caches=None, use_pallas=False)
        caches = [jax.lax.stop_gradient(cc) for cc in caches]
        z_t = q_sample(ab, x0, t, noise)
        eps, _, svals = model.forward(theta, gamma, cfg, z_t,
                                      t.astype(jnp.float32), y,
                                      caches=caches, use_pallas=False)
        return eps, svals

    def step_fn(theta, gamma, m, v, step, x0, y, t, t_prev, noise, lr,
                rho_attn, rho_ffn):
        def objective(gamma_):
            eps, svals = loss_fn(gamma_, theta, x0, y, t, t_prev, noise)
            dl = diffusion_loss(eps, noise)
            ll = lazy_loss(svals, rho_attn, rho_ffn)
            s_attn = jnp.mean(svals[0::2])
            s_ffn = jnp.mean(svals[1::2])
            frac_attn = jnp.mean((svals[0::2] > 0.5).astype(jnp.float32))
            frac_ffn = jnp.mean((svals[1::2] > 0.5).astype(jnp.float32))
            return dl + ll, (dl, ll, s_attn, s_ffn, frac_attn, frac_ffn)

        (_, (dl, ll, sa, sf, fa, ff)), g = jax.value_and_grad(
            objective, has_aux=True)(gamma)
        gamma, m, v = adamw_update(gamma, g, m, v, step, lr)
        return gamma, m, v, dl, ll, sa, sf, fa, ff

    return step_fn


def model_init_gates_const(cfg: ModelConfig) -> jnp.ndarray:
    """Constant gate vector for the cache-producing forward (gates unused
    there because caches=None ⇒ no blending; gate values are discarded)."""
    return model.init_gates(cfg)
