"""AOT exporter: lower every graph to HLO **text** + write the manifest.

HLO text (NOT `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the rust
`xla` 0.1.6 crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out ../artifacts --configs nano,xl-256a \
        --buckets 1,2,4,8,16 --train-batch 32 --goldens

Outputs under --out:
    <config>/<graph>.hlo.txt        one file per executable
    goldens/<config>/<graph>.in<i>.npy / .out<i>.npy   numeric goldens
    manifest.json                   shapes, offsets, file index
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, diffusion, graphs, model
from .configs import CONFIGS, DEFAULT_BUCKETS, DIFFUSION

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: the default ELIDES big constants as
    # `constant({...})`, silently zeroing baked weights (feature net) and
    # schedule tables (ᾱ in the train graphs) after the text round-trip.
    return comp.as_hlo_text(True)


def _golden_inputs(gd: graphs.GraphDef, cfg_name: str):
    """Deterministic, graph-specific inputs for golden dumps."""
    seed = int.from_bytes(
        hashlib.sha256(f"{cfg_name}/{gd.name}".encode()).digest()[:4], "little")
    key = jax.random.PRNGKey(seed)
    args = []
    for name, shape, dt in gd.inputs:
        key, sub = jax.random.split(key)
        if dt == "int32":
            hi = 10 if name == "y" else 999
            args.append(jax.random.randint(sub, shape, 0, hi, jnp.int32))
        elif dt == "uint32":
            args.append(jnp.array([seed & 0xFFFF, 42], jnp.uint32))
        elif name in ("lr",):
            args.append(jnp.float32(1e-3))
        elif name in ("rho_a", "rho_f"):
            args.append(jnp.float32(1e-3))
        elif name == "step":
            args.append(jnp.float32(1.0))
        elif name == "t" and len(shape) == 1 and dt == "float32":
            args.append(jnp.linspace(0.0, 999.0, shape[0], dtype=jnp.float32))
        elif name in ("theta", "gamma"):
            # well-conditioned weights: keep the golden computation stable
            # so the rust-vs-python tolerance can stay tight
            args.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        elif name == "m":
            args.append(jnp.zeros(shape, jnp.float32))
        elif name == "v":
            # second-moment state must be non-negative
            args.append(1e-4 * jnp.abs(jax.random.normal(sub, shape,
                                                         jnp.float32)))
        else:
            scale = 0.1 if len(shape) >= 2 else 0.5
            args.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return args


def export_graph(gd: graphs.GraphDef, out_dir: str, cfg_name: str,
                 goldens: bool):
    lowered = jax.jit(gd.fn).lower(*gd.example_args())
    text = to_hlo_text(lowered)
    fname = f"{cfg_name}/{gd.name}.hlo.txt"
    path = os.path.join(out_dir, fname)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)

    outputs_meta = None
    if goldens:
        args = _golden_inputs(gd, cfg_name)
        outs = jax.jit(gd.fn)(*args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        gdir = os.path.join(out_dir, "goldens", cfg_name)
        os.makedirs(gdir, exist_ok=True)
        for i, a in enumerate(args):
            np.save(os.path.join(gdir, f"{gd.name}.in{i}.npy"), np.asarray(a))
        outputs_meta = []
        for i, o in enumerate(outs):
            arr = np.asarray(o)
            np.save(os.path.join(gdir, f"{gd.name}.out{i}.npy"), arr)
            outputs_meta.append({"shape": list(arr.shape),
                                 "dtype": str(arr.dtype)})
    else:
        outputs_meta = []
        outs = jax.eval_shape(gd.fn, *gd.example_args())
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for o in outs:
            outputs_meta.append({"shape": list(o.shape),
                                 "dtype": str(o.dtype)})

    return {
        "file": fname,
        "inputs": [{"name": n, "shape": list(s), "dtype": d}
                   for n, s, d in gd.inputs],
        "outputs": outputs_meta,
    }


def export_config(cfg_name: str, out_dir: str, buckets, train_batch: int,
                  goldens: bool, train_goldens: bool):
    cfg = CONFIGS[cfg_name]
    entry = {
        "paper_analog": cfg.paper_analog,
        "model": {
            "img_size": cfg.img_size, "channels": cfg.channels,
            "patch": cfg.patch, "dim": cfg.dim, "depth": cfg.depth,
            "heads": cfg.heads, "num_classes": cfg.num_classes,
            "mlp_ratio": cfg.mlp_ratio, "freq_dim": cfg.freq_dim,
            "tokens": cfg.tokens, "patch_dim": cfg.patch_dim,
        },
        "diffusion": {
            "timesteps": DIFFUSION.timesteps,
            "beta_start": DIFFUSION.beta_start,
            "beta_end": DIFFUSION.beta_end,
        },
        "params": configs.spec_offsets(configs.param_spec(cfg)),
        "gates": configs.spec_offsets(configs.gate_spec(cfg)),
        "buckets": list(buckets),
        "train_batch": train_batch,
        "graphs": {},
    }
    for b in buckets:
        for gd in graphs.serving_graphs(cfg, b):
            print(f"  lowering {cfg_name}/{gd.name}")
            entry["graphs"][gd.name] = export_graph(gd, out_dir, cfg_name,
                                                    goldens)
    for gd in graphs.train_graphs(cfg, train_batch):
        print(f"  lowering {cfg_name}/{gd.name}")
        entry["graphs"][gd.name] = export_graph(
            gd, out_dir, cfg_name, goldens and train_goldens)
    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="nano,xl-256a")
    ap.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--goldens", action="store_true", default=True)
    ap.add_argument("--no-goldens", dest="goldens", action="store_false")
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    buckets = [int(b) for b in args.buckets.split(",")]
    manifest = {"version": 1, "feature_dim": 64, "configs": {}}

    # schedule golden: the Rust sampler must reproduce ᾱ exactly
    np.save(os.path.join(out_dir, "alphas_bar.npy"),
            np.asarray(diffusion.alphas_bar(DIFFUSION)))

    for cfg_name in args.configs.split(","):
        print(f"exporting {cfg_name}")
        # train-step goldens only for nano (they are large); the graph-
        # building code is identical across configs.
        manifest["configs"][cfg_name] = export_config(
            cfg_name, out_dir, buckets, args.train_batch,
            goldens=args.goldens, train_goldens=(cfg_name == "nano"))

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['configs'])} config(s)")


if __name__ == "__main__":
    main()
