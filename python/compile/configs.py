"""Model-configuration registry shared by the AOT exporter and the manifest.

Each config is a scaled-down analog of a paper model (DESIGN.md §4); the
Rust side reads the same values from artifacts/manifest.json, so this file
is the single source of truth for shapes.
"""

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    paper_analog: str
    img_size: int          # square images
    channels: int
    patch: int
    dim: int               # hidden D
    depth: int             # L transformer blocks
    heads: int
    num_classes: int = 10  # SynthBlobs-10
    mlp_ratio: int = 4
    freq_dim: int = 128    # sinusoidal timestep embedding width

    @property
    def tokens(self) -> int:
        side = self.img_size // self.patch
        return side * side

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def hidden(self) -> int:
        return self.dim * self.mlp_ratio


@dataclasses.dataclass(frozen=True)
class DiffusionConfig:
    timesteps: int = 1000
    beta_start: float = 1e-4
    beta_end: float = 2e-2


CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        # test-size model: seconds to pretrain, used across the test suites
        ModelConfig("nano", "(tests)", 8, 3, 2, 32, 2, 2),
        # paper-model analogs (see DESIGN.md §4 substitution table)
        ModelConfig("l-256a", "DiT-L/2 256", 8, 3, 2, 64, 4, 4),
        ModelConfig("xl-256a", "DiT-XL/2 256", 8, 3, 2, 96, 6, 6),
        ModelConfig("xl-512a", "DiT-XL/2 512", 16, 3, 2, 96, 6, 6),
        ModelConfig("l3b-a", "Large-DiT-3B", 8, 3, 2, 144, 8, 8),
        ModelConfig("l7b-a", "Large-DiT-7B", 8, 3, 2, 192, 10, 12),
    ]
}

DIFFUSION = DiffusionConfig()

# Batch buckets exported for the serving executables (continuous batcher
# pads to the next bucket; CFG doubles rows, hence 16).
DEFAULT_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16)


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of all *base* (frozen) parameters.

    The flat parameter vector θ concatenates these in order; the manifest
    publishes (name, shape, offset) so Rust can slice per-module weights
    out of one contiguous buffer.
    """
    D, F = cfg.dim, cfg.freq_dim
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed.patch.w", (cfg.patch_dim, D)),
        ("embed.patch.b", (D,)),
        ("embed.t.w1", (F, D)),
        ("embed.t.b1", (D,)),
        ("embed.t.w2", (D, D)),
        ("embed.t.b2", (D,)),
        # +1 class: the CFG null label
        ("embed.y.table", (cfg.num_classes + 1, D)),
    ]
    for l in range(cfg.depth):
        for mod in ("attn", "ffn"):
            spec += [
                (f"block{l}.{mod}.w_shift", (D, D)),
                (f"block{l}.{mod}.b_shift", (D,)),
                (f"block{l}.{mod}.w_scale", (D, D)),
                (f"block{l}.{mod}.b_scale", (D,)),
                (f"block{l}.{mod}.w_alpha", (D, D)),
                (f"block{l}.{mod}.b_alpha", (D,)),
            ]
        spec += [
            (f"block{l}.attn.w_qkv", (D, 3 * D)),
            (f"block{l}.attn.b_qkv", (3 * D,)),
            (f"block{l}.attn.w_o", (D, D)),
            (f"block{l}.attn.b_o", (D,)),
            (f"block{l}.ffn.w1", (D, cfg.hidden)),
            (f"block{l}.ffn.b1", (cfg.hidden,)),
            (f"block{l}.ffn.w2", (cfg.hidden, D)),
            (f"block{l}.ffn.b2", (D,)),
        ]
    spec += [
        ("final.w_shift", (D, D)),
        ("final.b_shift", (D,)),
        ("final.w_scale", (D, D)),
        ("final.b_scale", (D,)),
        ("final.w_out", (D, cfg.patch_dim)),
        ("final.b_out", (cfg.patch_dim,)),
    ]
    return spec


def gate_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of lazy-gate parameters γ (trainable)."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    for l in range(cfg.depth):
        for mod in ("attn", "ffn"):
            spec += [
                (f"gate{l}.{mod}.w", (cfg.dim,)),
                (f"gate{l}.{mod}.b", ()),
            ]
    return spec


def spec_size(spec) -> int:
    tot = 0
    for _, shape in spec:
        n = 1
        for d in shape:
            n *= d
        tot += n
    return tot


def spec_offsets(spec):
    """(name, shape, offset, size) rows for the manifest."""
    rows, off = [], 0
    for name, shape in spec:
        n = 1
        for d in shape:
            n *= d
        rows.append({"name": name, "shape": list(shape), "offset": off, "size": n})
        off += n
    return rows
