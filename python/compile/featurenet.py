"""Fixed random conv feature extractor for the FID/sFID/IS analogs.

The paper evaluates with InceptionV3 features; at toy scale we use a fixed
random-weight conv net (a standard proxy: random features preserve the
*ordering* of Fréchet distances well). Weights are generated from a fixed
seed and BAKED INTO THE GRAPH as constants, so the metric is identical
across runs, machines, and the python/rust boundary.

Outputs:
  feat  [B, 64] — deep features (FID / IS analog space)
  sfeat [B, 64] — spatially-aware earlier-layer features (sFID analog)
"""

import jax
import jax.numpy as jnp

FEATURE_SEED = 1234
FEATURE_DIM = 64


def _conv(x, w, stride):
    """NCHW conv, SAME padding."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def make_feature_fn(img_size: int, channels: int = 3):
    """Build feature_fn(img [B,C,H,W]) -> (feat [B,64], sfeat [B,64])."""
    key = jax.random.PRNGKey(FEATURE_SEED)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    c1, c2 = 16, 32
    w1 = jax.random.normal(k1, (c1, channels, 3, 3)) * (1.0 / 3.0)
    w2 = jax.random.normal(k2, (c2, c1, 3, 3)) * (1.0 / (3.0 * jnp.sqrt(c1 / 8)))

    s1 = img_size // 2          # after conv1 stride 2
    s2 = max(s1 // 2, 1)        # after conv2 stride 2
    p_sfeat = jax.random.normal(k3, (c1 * s1 * s1, FEATURE_DIM)) / jnp.sqrt(
        c1 * s1 * s1)
    p_feat = jax.random.normal(k4, (c2 * s2 * s2, FEATURE_DIM)) / jnp.sqrt(
        c2 * s2 * s2)
    del k5

    def feature_fn(img):
        h1 = jnp.maximum(_conv(img, w1, 2), 0.0)          # [B,c1,s1,s1]
        h2 = jnp.maximum(_conv(h1, w2, 2), 0.0)           # [B,c2,s2,s2]
        B = img.shape[0]
        sfeat = h1.reshape(B, -1) @ p_sfeat
        feat = h2.reshape(B, -1) @ p_feat
        return feat, sfeat

    return feature_fn
