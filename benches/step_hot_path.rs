//! Bench: per-step latency of the denoise hot path vs lazy ratio Γ,
//! plus micro-measurements of the two zero-copy mechanisms this repo's
//! skip path rides on (the memoized cache literal and the buffer
//! arena). Writes `BENCH_step.json` so the repo carries a perf
//! trajectory across PRs (docs/PERF.md explains how to read it).
//!
//! The Γ sweep runs the deterministic `SimEngine` (no artifacts / XLA
//! runtime needed): executed modules burn calibrated CPU, skipped ones
//! cost nothing, so per-step wall-clock must decrease monotonically
//! with Γ — asserted, not just reported.
//!
//! The cold-churn scenario compares the legacy all-or-nothing batch
//! gate against row-granular skipping under a periodic cold joiner at
//! Γ=0.9 and asserts the row-granular gate runs strictly fewer
//! row-weighted modules (`cold_churn.{coupled,row_granular}` in
//! `BENCH_step.json`).
//!
//! The warm-churn scenario replays the same periodic-joiner schedule
//! with joiners admitted via `submit_warm` from a same-family donor
//! snapshot: their step-0 cold denials must convert into skips
//! (`warm_churn.{cold_denied_cold,cold_denied_warm,rows_warmed}`),
//! the warm-start half of the result-cache PR.
//!
//!     cargo bench --bench step_hot_path
//!     BENCH_SMOKE=1 cargo bench --bench step_hot_path   # tiny CI gate
//!
//! (or `cargo run --release --bench step_hot_path` on toolchains where
//! bench profiles are unavailable)

use lazydit::coordinator::pool::sim::{SimEngine, SimSpec};
use lazydit::coordinator::pool::PoolEngine;
use lazydit::coordinator::request::Request;
use lazydit::metrics::stats::mean;
use lazydit::model::runner::BatchCaches;
use lazydit::obs::LatencyHist;
use lazydit::runtime::value::HostValue;
use lazydit::tensor::pool::TensorPool;
use lazydit::tensor::Tensor;
use lazydit::util::json::Json;
use std::hint::black_box;
use std::time::Instant;

struct BenchCfg {
    requests: usize,
    steps: usize,
    work: u64,
    micro_iters: usize,
    /// Cold-churn scenario shape (see `run_churn`).
    churn_residents: usize,
    churn_steps: usize,
    churn_period: usize,
    churn_joiners: usize,
}

struct GammaSeries {
    target_pct: u32,
    observed: f64,
    per_step_ms: Vec<f64>,
    /// Same samples in the serving stack's log-bucketed histogram —
    /// quantiles below come from here, not from sorting the Vec.
    hist: LatencyHist,
    cold_denied: u64,
    modules_run: u64,
}

/// One Γ point: flood the synthetic engine and time every round after
/// the first (round 0 is the cold-cache step — the steady state is what
/// the skip ratio accelerates).
fn run_gamma(lazy_pct: u32, cfg: &BenchCfg) -> GammaSeries {
    let mut e = SimEngine::new(SimSpec {
        lazy_pct,
        work_per_module: cfg.work,
        policy: format!("bench-g{lazy_pct}"),
        ..SimSpec::default()
    });
    for i in 0..cfg.requests {
        e.submit(Request::new(0, i % 10, cfg.steps, 42 + i as u64));
    }
    let mut per_step_ms = Vec::with_capacity(cfg.steps);
    let hist = LatencyHist::new();
    let mut round = 0usize;
    while e.active_count() > 0 {
        let t0 = Instant::now();
        e.step_round().expect("sim step");
        let dt_ms = t0.elapsed().as_secs_f64() * 1e3;
        if round > 0 {
            per_step_ms.push(dt_ms);
            hist.record_ms(dt_ms);
        }
        round += 1;
    }
    GammaSeries {
        target_pct: lazy_pct,
        observed: e.layer_stats.overall_ratio(),
        per_step_ms,
        hist,
        cold_denied: e.layer_stats.cold_denied_total(),
        modules_run: e.serve_stats.module_invocations
            - e.serve_stats.module_skips,
    }
}

/// Row-weighted outcome of one cold-churn run.
struct ChurnOutcome {
    rows_run: u64,
    rows_skipped: u64,
    rows_recovered: u64,
    cold_denied: u64,
    rows_warmed: u64,
}

impl ChurnOutcome {
    fn rows_total(&self) -> u64 {
        self.rows_run + self.rows_skipped
    }
}

/// The cold-churn scenario: a warm resident cohort at Γ=0.9 with a
/// periodic cold joiner (one fresh short request every `churn_period`
/// rounds). Both gate modes see the identical, fully deterministic
/// arrival schedule, so their row-weighted work is directly comparable:
/// the coupled (all-or-nothing) gate loses the residents' skips to
/// every cold joiner, the row-granular gate serves residents from cache
/// and runs only the joiner — `cold_churn.row_granular <
/// cold_churn.coupled` is the PR's acceptance inequality.
fn run_churn(coupled: bool, cfg: &BenchCfg) -> ChurnOutcome {
    let mut e = SimEngine::new(SimSpec {
        lazy_pct: 90,
        work_per_module: 500, // counts, not wall-clock, are asserted
        coupled,
        policy: format!("churn-{}",
                        if coupled { "coupled" } else { "rows" }),
        ..SimSpec::default()
    });
    for i in 0..cfg.churn_residents {
        e.submit(Request::new(0, i % 10, cfg.churn_steps, 900 + i as u64));
    }
    let mut round = 0usize;
    let mut joiners = 0usize;
    while e.active_count() > 0 {
        if round > 0 && round % cfg.churn_period == 0
            && joiners < cfg.churn_joiners
        {
            // the cold joiner: 2 steps, so every join contributes one
            // cold round and one warm round before retiring
            joiners += 1;
            e.submit(Request::new(0, joiners % 10, 2, 7_700 + joiners as u64));
        }
        e.step_round().expect("sim step");
        round += 1;
    }
    ChurnOutcome {
        rows_run: e.layer_stats.rows_run_total(),
        rows_skipped: e.layer_stats.rows_skipped_total(),
        rows_recovered: e.layer_stats.rows_recovered_total(),
        cold_denied: e.layer_stats.cold_denied_total(),
        rows_warmed: e.layer_stats.rows_warmed_total(),
    }
}

/// The warm-churn scenario: the identical periodic-joiner schedule as
/// [`run_churn`] (row-granular gate, Γ=0.9), except every joiner is the
/// donor's family-mate — same label, steps, cfg, lanes — and, when
/// `warm` is set, is admitted via `submit_warm` from a boundary
/// snapshot harvested off the first resident. A warm joiner's step-0
/// want-skips become real skips (counted as `rows_warmed`) instead of
/// cold denials, so on this deterministic schedule the warm pass must
/// show strictly fewer cold denials AND strictly fewer rows run than
/// the cold pass — the bench-level restatement of the warm-start
/// fidelity propcheck's accounting model.
fn run_warm_churn(warm: bool, cfg: &BenchCfg) -> ChurnOutcome {
    use lazydit::coordinator::request::TrajectorySnapshot;
    let mut e = SimEngine::new(SimSpec {
        lazy_pct: 90,
        work_per_module: 500, // counts, not wall-clock, are asserted
        policy: format!("warm-churn-{}", if warm { "on" } else { "off" }),
        ..SimSpec::default()
    });
    // one family: every resident (and every joiner) shares the donor's
    // (label, steps, cfg, lanes) key, so the donor is valid for all
    for i in 0..cfg.churn_residents {
        e.submit(Request::new(0, 3, cfg.churn_steps, 900 + i as u64));
    }
    let mut donor: Option<TrajectorySnapshot> = None;
    let mut round = 0usize;
    let mut joiners = 0usize;
    while e.active_count() > 0 {
        if donor.is_none() {
            // harvest the donor the moment a resident crosses its first
            // step boundary (cursor > 0 ⇒ usable warm horizon)
            donor = e
                .active_ids()
                .first()
                .and_then(|&id| e.snapshot_request(id))
                .filter(|s| s.cursor > 0);
        }
        if round > 0 && round % cfg.churn_period == 0
            && joiners < cfg.churn_joiners
        {
            joiners += 1;
            let req =
                Request::new(0, 3, cfg.churn_steps, 7_700 + joiners as u64);
            match donor.as_ref() {
                Some(d) if warm => {
                    e.submit_warm(req, d);
                }
                _ => {
                    e.submit(req);
                }
            }
        }
        e.step_round().expect("sim step");
        round += 1;
    }
    ChurnOutcome {
        rows_run: e.layer_stats.rows_run_total(),
        rows_skipped: e.layer_stats.rows_skipped_total(),
        rows_recovered: e.layer_stats.rows_recovered_total(),
        cold_denied: e.layer_stats.cold_denied_total(),
        rows_warmed: e.layer_stats.rows_warmed_total(),
    }
}

/// Micro: the skip path's cache read, before vs after the literal memo.
/// BEFORE is the pre-optimization shape (clone the `[B, N, D]` cache
/// tensor, convert it to a literal); AFTER is the memoized read.
fn literal_cache_micro(iters: usize) -> (f64, f64) {
    let (b, n, d) = (8usize, 16usize, 64usize);
    let mut caches = BatchCaches::empty(1, b, n, d);
    let f = Tensor::from_vec(&[b, n, d], vec![0.5; b * n * d]).unwrap();
    let lit = HostValue::f32_literal(&f).unwrap();
    caches.store_fresh(0, f, lit);

    let t0 = Instant::now();
    for _ in 0..iters {
        let t = caches.value(0).clone();
        black_box(HostValue::F32(t).to_literal().unwrap());
    }
    let before_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;

    let t0 = Instant::now();
    for _ in 0..iters {
        black_box(caches.literal(0).unwrap());
    }
    let after_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    (before_us, after_us)
}

/// Micro: a `[B, N, D]` buffer from the arena vs a fresh allocation.
fn arena_micro(iters: usize) -> (f64, f64) {
    let shape = [8usize, 16, 64];
    let pool = TensorPool::new();
    pool.release(pool.acquire(&shape)); // warm the size class

    let t0 = Instant::now();
    for _ in 0..iters {
        black_box(&Tensor::zeros(&shape));
    }
    let fresh_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;

    let t0 = Instant::now();
    for _ in 0..iters {
        let t = pool.acquire(&shape);
        pool.release(black_box(t));
    }
    let pooled_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    assert_eq!(pool.stats().allocated, 1, "steady state must not allocate");
    (fresh_us, pooled_us)
}

/// Micro: the portable-snapshot path a migration rides — evict a
/// mid-flight trajectory to a [`TrajectorySnapshot`], the versioned wire
/// encode/decode, and the full evict→admit cycle on the engine.
/// Returns (cycle_us, encode_us, decode_us, snapshot_bytes).
///
/// [`TrajectorySnapshot`]: lazydit::coordinator::request::TrajectorySnapshot
fn snapshot_micro(iters: usize) -> (f64, f64, f64, usize) {
    use lazydit::coordinator::request::TrajectorySnapshot;
    let mut e = SimEngine::new(SimSpec {
        lazy_pct: 50,
        work_per_module: 50,
        policy: "snap-micro".into(),
        ..SimSpec::default()
    });
    let mut id = e.submit(Request::new(0, 3, 16, 4242));
    for _ in 0..4 {
        e.step_round().expect("sim step");
    }

    let snap = e.snapshot_request(id).expect("boundary snapshot");
    let bytes = snap.encode();
    let t0 = Instant::now();
    for _ in 0..iters {
        black_box(snap.encode());
    }
    let encode_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;

    let t0 = Instant::now();
    for _ in 0..iters {
        black_box(TrajectorySnapshot::decode(&bytes).expect("decode"));
    }
    let decode_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;

    // the engine-side cycle: leave and rejoin the active set at the
    // same boundary each iteration (residency returns to steady state)
    let t0 = Instant::now();
    for _ in 0..iters {
        let s = e.evict_to_snapshot(id).expect("evict");
        id = e.admit_snapshot(black_box(s));
    }
    let cycle_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    assert_eq!(e.active_count(), 1, "cycle must preserve residency");
    (cycle_us, encode_us, decode_us, bytes.len())
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let cfg = if smoke {
        BenchCfg { requests: 2, steps: 6, work: 25_000, micro_iters: 50,
                   churn_residents: 3, churn_steps: 8, churn_period: 2,
                   churn_joiners: 3 }
    } else {
        BenchCfg { requests: 4, steps: 40, work: 50_000, micro_iters: 2_000,
                   churn_residents: 4, churn_steps: 32, churn_period: 2,
                   churn_joiners: 12 }
    };
    println!("step_hot_path: per-step latency vs Γ (SimEngine, \
              {} requests × {} steps, work/module {}{})",
             cfg.requests, cfg.steps, cfg.work,
             if smoke { ", SMOKE" } else { "" });

    let mut series = Vec::new();
    for pct in [0u32, 50, 90] {
        let s = run_gamma(pct, &cfg);
        let (p50, p95) = (s.hist.quantile_ms(0.5), s.hist.quantile_ms(0.95));
        println!("  Γ target {:>2}%  observed {:>5.1}%   per-step mean \
                  {:>8.3}ms  p50 {:>8.3}ms  p95 {:>8.3}ms   \
                  ({} modules run, {} cold-denied)",
                 pct, 100.0 * s.observed, mean(&s.per_step_ms), p50, p95,
                 s.modules_run, s.cold_denied);
        series.push(s);
    }

    // the acceptance property: laziness must translate into wall-clock —
    // strictly fewer modules executed AND strictly lower per-step latency
    // as Γ grows. The modules-run ordering is deterministic and always
    // strict; the wall-clock ordering is strict on the full run but
    // advisory in smoke mode, where ~5 sub-millisecond samples per
    // series would let one OS preemption flake the whole CI gate.
    for w in series.windows(2) {
        assert!(w[0].observed < w[1].observed,
                "observed Γ must grow with the target");
        assert!(w[0].modules_run > w[1].modules_run,
                "modules executed must fall as Γ grows");
        let (lo, hi) = (mean(&w[1].per_step_ms), mean(&w[0].per_step_ms));
        if hi <= lo {
            let msg = format!(
                "per-step latency not monotone: {hi:.4}ms at Γ={} vs \
                 {lo:.4}ms at Γ={}",
                w[0].target_pct, w[1].target_pct);
            if smoke {
                eprintln!("  WARN (smoke, advisory): {msg}");
            } else {
                panic!("{msg}");
            }
        }
    }

    // ---- cold-churn: the row-granular acceptance comparison. One cold
    // joiner every churn_period rounds at Γ=0.9; row-weighted
    // modules-run must be STRICTLY lower than the all-or-nothing
    // baseline on the identical schedule (deterministic, so this is a
    // hard assert even in smoke mode).
    let coupled = run_churn(true, &cfg);
    let rowg = run_churn(false, &cfg);
    println!("  cold churn (Γ=0.9, joiner every {} rounds × {}): \
              rows run {} (coupled) → {} (row-granular), {} recovered, \
              cold-denied {} → {}",
             cfg.churn_period, cfg.churn_joiners, coupled.rows_run,
             rowg.rows_run, rowg.rows_recovered, coupled.cold_denied,
             rowg.cold_denied);
    assert_eq!(coupled.rows_total(), rowg.rows_total(),
               "identical schedule must offer identical row-work");
    assert!(rowg.rows_run < coupled.rows_run,
            "row-granular skipping must run strictly fewer rows under \
             churn ({} vs {})", rowg.rows_run, coupled.rows_run);
    assert!(rowg.rows_recovered > 0,
            "resident skips during cold rounds must count as recovered");

    // ---- warm churn: same schedule, joiners warm-started from a donor
    // snapshot. Deterministic, so hard asserts even in smoke mode.
    let wcold = run_warm_churn(false, &cfg);
    let wwarm = run_warm_churn(true, &cfg);
    println!("  warm churn (Γ=0.9, joiner every {} rounds × {}): \
              cold-denied {} (cold joins) → {} (warm joins), \
              {} rows warmed, rows run {} → {}",
             cfg.churn_period, cfg.churn_joiners, wcold.cold_denied,
             wwarm.cold_denied, wwarm.rows_warmed, wcold.rows_run,
             wwarm.rows_run);
    assert_eq!(wcold.rows_total(), wwarm.rows_total(),
               "identical schedule must offer identical row-work");
    assert_eq!(wcold.rows_warmed, 0,
               "cold joins must not report warmed rows");
    assert!(wwarm.rows_warmed > 0,
            "warm joins must seed rows at admission");
    assert!(wwarm.cold_denied < wcold.cold_denied,
            "warm starts must convert cold denials into skips ({} vs {})",
            wwarm.cold_denied, wcold.cold_denied);
    assert!(wwarm.rows_run < wcold.rows_run,
            "warm starts must run strictly fewer rows ({} vs {})",
            wwarm.rows_run, wcold.rows_run);

    let (lit_before, lit_after) = literal_cache_micro(cfg.micro_iters);
    println!("  literal cache: clone+convert {lit_before:.2}µs → memo \
              {lit_after:.3}µs per skip read  ({:.0}x)",
             lit_before / lit_after.max(1e-9));
    let (fresh, pooled) = arena_micro(cfg.micro_iters);
    println!("  arena: fresh alloc {fresh:.2}µs → pooled {pooled:.2}µs \
              per [8,16,64] buffer");
    let (snap_cycle, snap_enc, snap_dec, snap_bytes) =
        snapshot_micro(cfg.micro_iters);
    println!("  snapshot: evict→admit {snap_cycle:.2}µs, wire encode \
              {snap_enc:.2}µs / decode {snap_dec:.2}µs ({snap_bytes} B \
              mid-flight)");

    let json = Json::obj(vec![
        ("bench", Json::str("step_hot_path")),
        ("smoke", Json::Bool(smoke)),
        ("requests", Json::num(cfg.requests as f64)),
        ("steps", Json::num(cfg.steps as f64)),
        ("work_per_module", Json::num(cfg.work as f64)),
        ("series", Json::arr(series.iter().map(|s| {
            Json::obj(vec![
                ("gamma_target", Json::num(s.target_pct as f64 / 100.0)),
                ("gamma_observed", Json::num(s.observed)),
                ("per_step_ms", Json::obj(vec![
                    ("mean", Json::num(mean(&s.per_step_ms))),
                    ("p50", Json::num(s.hist.quantile_ms(0.5))),
                    ("p95", Json::num(s.hist.quantile_ms(0.95))),
                    ("p99", Json::num(s.hist.quantile_ms(0.99))),
                ])),
                ("steps_timed", Json::num(s.per_step_ms.len() as f64)),
                ("modules_run", Json::num(s.modules_run as f64)),
                ("cold_denied", Json::num(s.cold_denied as f64)),
            ])
        }))),
        // the acceptance pair: row-weighted modules-run under churn,
        // coupled vs row-granular (strictly lower required)
        ("cold_churn", Json::obj(vec![
            ("gamma_target", Json::num(0.9)),
            ("rows_total", Json::num(rowg.rows_total() as f64)),
            ("coupled", Json::num(coupled.rows_run as f64)),
            ("row_granular", Json::num(rowg.rows_run as f64)),
            ("rows_recovered", Json::num(rowg.rows_recovered as f64)),
            ("cold_denied_coupled", Json::num(coupled.cold_denied as f64)),
            ("cold_denied_row_granular",
             Json::num(rowg.cold_denied as f64)),
        ])),
        // the warm-start pair: step-0 cold denials with cold vs
        // warm-started joiners on the identical schedule (strictly
        // lower, plus rows_warmed > 0, required)
        ("warm_churn", Json::obj(vec![
            ("gamma_target", Json::num(0.9)),
            ("rows_total", Json::num(wwarm.rows_total() as f64)),
            ("cold_denied_cold", Json::num(wcold.cold_denied as f64)),
            ("cold_denied_warm", Json::num(wwarm.cold_denied as f64)),
            ("rows_warmed", Json::num(wwarm.rows_warmed as f64)),
            ("rows_run_cold", Json::num(wcold.rows_run as f64)),
            ("rows_run_warm", Json::num(wwarm.rows_run as f64)),
        ])),
        ("literal_cache_us", Json::obj(vec![
            ("clone_convert", Json::num(lit_before)),
            ("memo", Json::num(lit_after)),
        ])),
        ("arena_us", Json::obj(vec![
            ("fresh_alloc", Json::num(fresh)),
            ("pooled", Json::num(pooled)),
        ])),
        // the migration tax: what one evict→admit hop and the wire
        // codec cost a mid-flight trajectory (docs/SERVING.md)
        ("snapshot_us", Json::obj(vec![
            ("evict_admit", Json::num(snap_cycle)),
            ("encode", Json::num(snap_enc)),
            ("decode", Json::num(snap_dec)),
            ("bytes", Json::num(snap_bytes as f64)),
        ])),
    ]);
    std::fs::write("BENCH_step.json", format!("{json}\n"))
        .expect("write BENCH_step.json");
    println!("  wrote BENCH_step.json");
}
