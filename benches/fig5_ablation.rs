//! Bench: regenerate paper Figure 5 (upper: maximum applicable laziness
//! per individual module; lower: fixed-one-sweep-other lazy strategy).
//! LAZYDIT_BENCH_FULL=1 widens the ratio grid.

fn main() {
    let full = std::env::var("LAZYDIT_BENCH_FULL").is_ok();
    let ratios = if full { "10,20,30,40,50" } else { "30" };
    for part in ["upper", "lower"] {
        let argv = vec![
            "fig5".to_string(),
            "--part".into(), part.into(),
            "--ratios".into(), ratios.into(),
            "--n-eval".into(), "32".into(),
            "--n-real".into(), "160".into(),
            "--train-steps".into(), "80".into(),
        ];
        if let Err(e) = lazydit::cli::dispatch(&argv) {
            eprintln!("fig5 {part} bench failed: {e:#}");
            std::process::exit(1);
        }
    }
}
