//! Bench: regenerate paper Table 6 (batched latency, A5000 analog —
//! 8 images per batch = 16 CFG lanes through the continuous batcher).

fn main() {
    let full = std::env::var("LAZYDIT_BENCH_FULL").is_ok();
    let mut argv = vec![
        "table6".to_string(),
        "--n-eval".into(), "8".into(),
        "--n-real".into(), "128".into(),
    ];
    if !full {
        argv.push("--quick".into());
    }
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("table6 bench failed: {e:#}");
        std::process::exit(1);
    }
}
