//! Bench: regenerate paper Table 3 (single-stream latency — the mobile
//! analog; see DESIGN.md §4 substitutions). One request in flight, CFG
//! lanes only, latency per image reported alongside TMACs and IS.

fn main() {
    let full = std::env::var("LAZYDIT_BENCH_FULL").is_ok();
    let mut argv = vec![
        "table3".to_string(),
        "--n-eval".into(), "8".into(),
        "--n-real".into(), "128".into(),
    ];
    if !full {
        argv.push("--quick".into());
    }
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("table3 bench failed: {e:#}");
        std::process::exit(1);
    }
}
