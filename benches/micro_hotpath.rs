//! Bench: engine hot-path micro benchmarks (per-step breakdown, skip-all
//! vs no-skip bounds) — the §Perf measurement harness for L3.

fn main() {
    let argv = vec![
        "profile".to_string(),
        "--steps".into(), "10".into(),
        "--count".into(), "4".into(),
        "--iters".into(), "5".into(),
    ];
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("micro_hotpath bench failed: {e:#}");
        std::process::exit(1);
    }
}
