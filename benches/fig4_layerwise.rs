//! Bench: regenerate paper Figure 4 (layer-wise laziness distribution of
//! MHSA vs FFN over a 20-step DDIM run; paper observation: no layer is
//! 100% lazy, so layer REMOVAL is not applicable).

fn main() {
    let argv = vec![
        "fig4".to_string(),
        "--steps".into(), "20".into(),
        "--lazy".into(), "50".into(),
    ];
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("fig4 bench failed: {e:#}");
        std::process::exit(1);
    }
}
