//! Bench: replica-pool scaling on the synthetic workload.
//!
//! Closed-loop part: sweeps the pool 1→N replicas (flood of the same
//! request set), reporting requests/sec and latency p50/p99 per point,
//! then compares routing policies at the widest pool, then runs the
//! skewed-Γ scenario: replicas whose lazy ratios diverge, where
//! admission-time jsq placement strands work on the slow
//! (never-skipping) replica and work stealing pulls it back. Also
//! verifies the determinism contract: result images are byte-identical
//! to the single-replica reference for every (seed, label, steps).
//!
//! Open-loop part: Poisson arrivals from `data::workload::WorkloadSpec`
//! against a heterogeneous SLO-tiered pool (one B1 latency replica +
//! three B8 throughput replicas), sweeping offered load below/at/above
//! the measured capacity and charting shed rate and p50/p95 completion
//! latency **per SLO tier** and per route policy. Unlike the
//! closed-loop flood, arrival times don't wait for completions, so the
//! numbers include queueing delay honestly (no coordinated omission —
//! see docs/BENCHMARKS.md).
//!
//! Cache part: a deterministic Zipf-label workload through a
//! cache-fronted replica — exact-result hits, warm-start donors, the
//! `dispatched == completed + cache_hits + shed + forfeited` ledger,
//! and the strict cold-denial reduction are all asserted, and the
//! numbers land in the `cache` section of `BENCH_serve.json`. With
//! `BENCH_SMOKE=1` only this part runs (the tier-1 gate).
//!
//! Chaos part: deterministic fault schedules (panic, panic-rate sweep,
//! stall, queue-full burst, snapshot corruption) through a supervised
//! stealing pool. Under EVERY schedule the admission ledger
//! `dispatched == completed + cache_hits + shed + forfeited` must
//! balance exactly and no request may strand; a supervised pool must
//! strictly out-complete an unsupervised one under the identical panic
//! schedule, and the brownout degradation ladder must shed strictly
//! less at every stage under identical overload. The numbers land in
//! the `chaos` section of `BENCH_serve.json` (docs/SERVING.md).
//!
//! Latency quantiles come from the same mergeable log-bucketed
//! histograms the serving `STATS` verb reports ([`lazydit::obs`], ≤12.5%
//! relative error), not from sorting sample vectors. A final traced
//! vs untraced closed-loop pass measures telemetry-ring overhead, and
//! the per-tier quantiles plus that delta land in `BENCH_serve.json`
//! (docs/OBSERVABILITY.md).
//!
//!     cargo bench --bench pool_scaling
//! (or `cargo run --release --bench pool_scaling` on toolchains where
//! bench profiles are unavailable)

use lazydit::config::{RoutePolicy, Slo};
use lazydit::coordinator::pool::replica::{ReplicaHandle, ReplicaTier};
use lazydit::coordinator::pool::sim::{sim_image, SimEngine, SimSpec};
use lazydit::coordinator::pool::steal::Rebalancer;
use lazydit::coordinator::pool::{
    Brownout, BrownoutConfig, CacheConfig, FaultPlan, PoolCache,
    PoolCalendar, PoolEngine, PoolReport, RespawnFactory, Router,
    SkipCalendar, Supervisor, SupervisorConfig,
};
use lazydit::coordinator::request::Request;
use lazydit::data::workload::WorkloadSpec;
use lazydit::obs::{epoch_us, LatencyHist, Tracer};
use lazydit::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const REQUESTS: usize = 64;
const STEPS: usize = 10;
const WORK: u64 = 20_000;
const LAZY_PCT: u32 = 50;
/// In-engine admission bound while stealing (jobs beyond it stay
/// queued, i.e. migratable).
const STEAL_WINDOW: usize = 2;
/// Per-replica trace ring capacity for the traced overhead pass.
const TRACE_RING: usize = 4096;

fn spec() -> SimSpec {
    SimSpec { lazy_pct: LAZY_PCT, work_per_module: WORK, ..SimSpec::default() }
}

fn workload() -> Vec<Request> {
    (0..REQUESTS)
        .map(|i| Request::new(0, i % 10, STEPS, 7_000 + i as u64))
        .collect()
}

fn fnv64(data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in data {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

struct RunResult {
    wall_s: f64,
    /// Client-observed completion latency (dispatch → response), which
    /// includes queue wait — the quantity stealing actually improves.
    /// Recorded concurrently by the collector threads into the same
    /// mergeable log-bucketed histogram structure `STATS` serves.
    hist: Arc<LatencyHist>,
    checksums: Vec<u64>,
    shed: u64,
    report: PoolReport,
}

fn run_pool_with(specs: Vec<SimSpec>, route: RoutePolicy, steal: bool,
                 traced: bool) -> RunResult {
    let rebalancer = steal.then(|| Rebalancer::new(STEAL_WINDOW));
    let handles: Vec<ReplicaHandle> = specs
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            let tier = match &rebalancer {
                Some(rb) => ReplicaTier {
                    steal_window: rb.admit_window(),
                    ..ReplicaTier::default()
                },
                None => ReplicaTier::default(),
            };
            let tracer = if traced {
                Tracer::enabled(i, TRACE_RING)
            } else {
                Tracer::disabled()
            };
            ReplicaHandle::spawn_traced(i, 4096, SimEngine::factory(s),
                                        rebalancer.clone(), tier, tracer)
            .unwrap()
        })
        .collect();
    let router = Router::with_rebalancer(handles, route, 4096, rebalancer);
    let hist = Arc::new(LatencyHist::new());
    let t0 = Instant::now();
    // one collector thread per request so completion timestamps are
    // observed the moment each response lands, not in dispatch order
    let mut joins = Vec::with_capacity(REQUESTS);
    for req in workload() {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(req, tx), "closed-loop run must not shed");
        let h = hist.clone();
        joins.push(std::thread::spawn(move || {
            let res = rx.recv().expect("response");
            h.record_secs(t0.elapsed().as_secs_f64());
            fnv64(res.image.data())
        }));
    }
    let mut checksums = Vec::with_capacity(REQUESTS);
    for j in joins {
        checksums.push(j.join().expect("collector"));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = router.shutdown();
    checksums.sort_unstable();
    RunResult { wall_s, hist, checksums, shed: report.shed, report }
}

fn run_pool(replicas: usize, route: RoutePolicy) -> RunResult {
    run_pool_with(vec![spec(); replicas], route, false, false)
}

fn row(label: &str, r: &RunResult) -> String {
    format!(
        "  {:<16} {:>9.1} req/s   p50 {:>8.2}ms   p95 {:>8.2}ms   ({} shed)",
        label,
        REQUESTS as f64 / r.wall_s,
        r.hist.quantile_ms(0.5),
        r.hist.quantile_ms(0.95),
        r.shed,
    )
}

/// The skewed-Γ scenario: half the pool never skips (Γ=0), half skips
/// aggressively (Γ≈90%). jsq balances *queue lengths* at admission, so
/// without stealing the slow replica strands ~half the workload; with
/// stealing the fast replica pulls the slow one's queued jobs as it
/// goes idle. Returns (p95 without stealing, p95 with stealing).
fn skewed_gamma_scenario() -> (f64, f64) {
    let specs = || vec![SimSpec::with_lazy(0, WORK),
                        SimSpec::with_lazy(90, WORK)];
    println!("skewed-Γ scenario (2 replicas, Γ = 0% vs 90%, route jsq):");
    let base = run_pool_with(specs(), RoutePolicy::Jsq, false, false);
    println!("{}", row("jsq", &base));
    let stealing = run_pool_with(specs(), RoutePolicy::Jsq, true, false);
    println!("{}", row("jsq + steal", &stealing));
    for r in &stealing.report.replicas {
        println!("    replica {} ({:<8}): served {:>3}, stole {:>3}, \
                  lost {:>3}",
                 r.id, r.policy, r.serve.completed, r.steals, r.stolen);
    }
    let (steals, stolen) = (stealing.report.total_steals(),
                            stealing.report.total_stolen());
    assert_eq!(steals, stolen,
               "migration conservation: every steal has one thief and \
                one victim");
    // the pool exercises the row-granular gate end to end: every
    // simulated module invocation is one row, so row-work partitions
    // the invocation count exactly, and Γ-skewed replicas serving
    // several concurrent trajectories recover rows a coupled batch
    // gate would have denied
    let merged = stealing.report.merged_layer();
    let serve = stealing.report.merged_serve();
    assert_eq!(merged.rows_run_total() + merged.rows_skipped_total(),
               serve.module_invocations,
               "row-work must partition module invocations exactly");
    println!("    row-granular gate: {}/{} rows skipped, {} recovered",
             merged.rows_skipped_total(),
             merged.rows_run_total() + merged.rows_skipped_total(),
             merged.rows_recovered_total());
    assert_eq!(
        stealing.report.completed() + base.report.completed(),
        2 * REQUESTS,
        "no job lost or duplicated across either run"
    );
    let p95_base = base.hist.quantile_ms(0.95) / 1e3;
    let p95_steal = stealing.hist.quantile_ms(0.95) / 1e3;
    (p95_base, p95_steal)
}

/// Mid-sweep tier retag: a throughput-only pool is shedding latency
/// traffic, so one bulk replica is retagged `latency` while its
/// trajectories are mid-flight. The retag drains those residents to the
/// remaining throughput siblings as portable snapshots (drain-by-
/// migration) and the pool starts serving latency — with ZERO stranded
/// requests: everything admitted before, during, and after the retag
/// completes exactly once. Returns the `migration` section of
/// `BENCH_serve.json`.
fn retag_scenario() -> Json {
    const BULK: usize = 48;
    const LAT: usize = 6;
    println!("mid-sweep retag scenario (thr:b8x3 → retag replica 0 to \
              latency under load):");
    let rb = Rebalancer::new(STEAL_WINDOW);
    let handles: Vec<ReplicaHandle> = (0..3)
        .map(|i| {
            ReplicaHandle::spawn_tiered(
                i, 4096, SimEngine::factory(spec()), Some(rb.clone()),
                ReplicaTier {
                    steal_window: rb.admit_window(),
                    ..ReplicaTier::new(Slo::Throughput, 8)
                })
            .unwrap()
        })
        .collect();
    let router =
        Router::with_rebalancer(handles, RoutePolicy::Jsq, 4096, Some(rb));

    // the latency demand the retag answers: unservable today
    let (tx, rx) = mpsc::channel();
    let mut probe = Request::new(0, 1, STEPS, 90_000).with_slo(Slo::Latency);
    probe.cfg_scale = 1.0;
    assert!(!router.dispatch(probe, tx),
            "a throughput-only pool must shed latency traffic");
    drop(rx);
    assert_eq!(router.shed_by_slo()[Slo::Latency.index()], 1);

    let mut rxs = Vec::with_capacity(BULK + LAT);
    for i in 0..BULK {
        let (tx, rx) = mpsc::channel();
        let req = Request::new(0, i % 10, STEPS, 91_000 + i as u64)
            .with_slo(Slo::Throughput);
        assert!(router.dispatch(req, tx), "bulk dispatch must admit");
        rxs.push(rx);
    }
    // let trajectories get resident, then retag; re-arm until the drain
    // sweep actually catches one mid-flight (an empty engine migrates
    // nothing)
    std::thread::sleep(std::time::Duration::from_millis(5));
    let mut tries = 0u32;
    loop {
        router.retag_replica(0, Slo::Latency);
        std::thread::sleep(std::time::Duration::from_millis(2));
        tries += 1;
        if router.total_migrated() > 0 || tries > 500 {
            break;
        }
    }
    // the pool now serves the class it was shedding
    for i in 0..LAT {
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(0, i % 10, STEPS, 92_000 + i as u64)
            .with_slo(Slo::Latency);
        req.cfg_scale = 1.0;
        assert!(router.dispatch(req, tx),
                "post-retag latency dispatch must admit");
        rxs.push(rx);
    }
    let mut stranded = 0usize;
    for rx in rxs {
        if rx.recv().is_err() {
            stranded += 1;
        }
    }
    let report = router.shutdown();
    assert_eq!(stranded, 0, "a mid-sweep retag must strand zero requests");
    assert_eq!(report.completed(), BULK + LAT);
    assert_eq!(router.total_forfeited(), 0);
    assert!(report.total_migrated_out() >= 1,
            "the retag drain must relocate at least one resident");
    assert_eq!(report.total_migrated_out(), report.total_migrated_in(),
               "every evicted snapshot resumed exactly once");
    assert!(report.total_resumed() >= 1);
    assert_eq!(
        report.replicas[0].completed_by_slo[Slo::Latency.index()],
        LAT as u64,
        "all post-retag latency traffic lands on the retagged replica");
    println!(
        "  retag drained {} resident(s) ({} steps saved), {} resumed, \
         0 stranded; replica 0 then served {LAT} latency request(s)",
        report.total_migrated_out(),
        report.total_resume_steps_saved(),
        report.total_resumed());
    Json::obj(vec![
        ("retagged_replicas", Json::num(1.0)),
        ("migrated_out", Json::num(report.total_migrated_out() as f64)),
        ("migrated_in", Json::num(report.total_migrated_in() as f64)),
        ("resumed", Json::num(report.total_resumed() as f64)),
        ("resume_steps_saved",
         Json::num(report.total_resume_steps_saved() as f64)),
        ("stranded", Json::num(stranded as f64)),
        ("latency_served_after_retag", Json::num(LAT as f64)),
    ])
}

// -------------------------------------------------------------- cache

/// Requests in the cache scenario's Zipf workload.
const CACHE_REQUESTS: usize = 48;
/// Denoise steps per cache-scenario request (small so donors within the
/// warm horizon cover a meaningful share of each trajectory).
const CACHE_STEPS: usize = 8;
/// Warm-start donor horizon for the warm-on pass.
const CACHE_HORIZON: usize = 3;

/// Deterministic Zipf-ish label workload over a small seed pool: class
/// 0 takes half the traffic, tails shrink harmonically, and only 4
/// distinct seeds circulate per class — so exact (label, seed) repeats
/// hit the result tier and same-class/new-seed requests warm-start
/// from donors. Rebuilt per run (not cloned) so every pass replays the
/// byte-identical sequence.
fn cache_workload() -> Vec<Request> {
    (0..CACHE_REQUESTS)
        .map(|i| {
            let r = (i * i * 7 + 3) % 12;
            let label = match r {
                0..=5 => 0,
                6..=8 => 1,
                9 | 10 => 2,
                _ => 3,
            };
            Request::new(0, label, CACHE_STEPS, 55_000 + (i % 4) as u64)
        })
        .collect()
}

/// Outcome of one serial closed-loop pass over [`cache_workload`].
struct CacheRun {
    hist: LatencyHist,
    report: PoolReport,
    dispatched: u64,
    forfeited: u64,
}

/// Serve the Zipf workload through a single cache-fronted replica,
/// serially (each response received before the next dispatch, so the
/// cache is populated before its repeats arrive — a deterministic hit
/// pattern). Every response is checked byte-identical to the pure
/// reference image: an exact hit or a warm start that changed output
/// bytes fails here, not in a downstream consumer.
fn run_cache_pass(cache_capacity: usize, warm_horizon: usize) -> CacheRun {
    let elems = spec().img_elems;
    let cache = (cache_capacity > 0).then(|| {
        Arc::new(PoolCache::new(CacheConfig::new(
            cache_capacity, warm_horizon, 0xC0FF_EE00)))
    });
    let handle = ReplicaHandle::spawn_cached(
        0, 256, SimEngine::factory(spec()), None, ReplicaTier::default(),
        Tracer::disabled(), cache.clone())
        .unwrap();
    let router = Router::with_cache(vec![handle], RoutePolicy::Jsq, 256,
                                    None, cache);
    let hist = LatencyHist::new();
    for req in cache_workload() {
        let reference = fnv64(sim_image(&req, elems).data());
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        assert!(router.dispatch(req, tx), "cache pass must not shed");
        let res = rx.recv().expect("response");
        hist.record_secs(t0.elapsed().as_secs_f64());
        assert_eq!(fnv64(res.image.data()), reference,
                   "cache/warm-start output must be byte-identical to \
                    the cold reference (id {})", res.id);
    }
    let dispatched = router.total_dispatched();
    let forfeited = router.total_forfeited();
    let report = router.shutdown();
    // the conservation law with its cache term — every dispatch settles
    // exactly once even when the engine never saw the request
    assert_eq!(dispatched,
               report.completed() as u64 + report.cache_hits
                   + report.shed + forfeited,
               "conservation: dispatched == completed + cache_hits + \
                shed + forfeited");
    CacheRun { hist, report, dispatched, forfeited }
}

/// The cache scenario: Zipf labels over a small seed pool, three
/// passes — cache off (latency baseline), exact tier only, exact tier +
/// warm-start donors. Asserts exact hits actually occur, that the hit
/// pattern is independent of the warm tier, and that warm starts
/// strictly reduce cold-row denials under the identical workload.
/// Returns the `cache` section of `BENCH_serve.json`.
fn cache_scenario() -> Json {
    println!("cache scenario ({CACHE_REQUESTS} Zipf requests × \
              {CACHE_STEPS} steps, 4 seeds/class, horizon \
              {CACHE_HORIZON}):");
    let off = run_cache_pass(0, 0);
    let exact = run_cache_pass(64, 0);
    let warm = run_cache_pass(64, CACHE_HORIZON);

    assert_eq!(off.report.cache_hits, 0, "no cache, no hits");
    assert!(exact.report.cache_hits > 0,
            "the Zipf workload repeats (label, seed) pairs — the exact \
             tier must hit");
    assert_eq!(exact.report.cache_hits, warm.report.cache_hits,
               "exact-hit pattern must not depend on the warm tier");
    assert_eq!(warm.dispatched, CACHE_REQUESTS as u64);
    assert_eq!(exact.forfeited + warm.forfeited, 0);

    // horizon 0 admits everything cold; horizon 3 converts step-0
    // would-skips into skips on warm rows — strictly less cold denial
    let (cold_off, cold_on) = (exact.report.total_cold_denied(),
                               warm.report.total_cold_denied());
    assert_eq!(exact.report.total_rows_warmed(), 0,
               "horizon 0 must never warm a row");
    assert!(warm.report.total_warm_hits() > 0,
            "same-class/new-seed requests must find donors");
    assert!(warm.report.total_rows_warmed() > 0);
    assert!(cold_on < cold_off,
            "warm starts must strictly reduce cold-row denials \
             ({cold_off} -> {cold_on})");

    let hit_ratio =
        exact.report.cache_hits as f64 / CACHE_REQUESTS as f64;
    println!("  exact hits {}/{CACHE_REQUESTS} ({:.0}%), warm starts {} \
              ({} rows warmed), cold-denied {cold_off} -> {cold_on}",
             exact.report.cache_hits, 100.0 * hit_ratio,
             warm.report.total_warm_hits(),
             warm.report.total_rows_warmed());
    println!("  p95 {:.2}ms (cache off) -> {:.2}ms (exact + warm)",
             off.hist.quantile_ms(0.95), warm.hist.quantile_ms(0.95));
    Json::obj(vec![
        ("requests", Json::num(CACHE_REQUESTS as f64)),
        ("hit_ratio", Json::num(hit_ratio)),
        ("cache_hits", Json::num(exact.report.cache_hits as f64)),
        ("warm_hits", Json::num(warm.report.total_warm_hits() as f64)),
        ("rows_warmed",
         Json::num(warm.report.total_rows_warmed() as f64)),
        ("cold_denied_warm_off", Json::num(cold_off as f64)),
        ("cold_denied_warm_on", Json::num(cold_on as f64)),
        ("cold_rows_recovered",
         Json::num((cold_off - cold_on) as f64)),
        ("p95_ms_cache_off", Json::num(off.hist.quantile_ms(0.95))),
        ("p95_ms_cache_on", Json::num(warm.hist.quantile_ms(0.95))),
    ])
}

// -------------------------------------------------------------- chaos

/// Requests per chaos schedule run.
const CHAOS_REQUESTS: usize = 32;
/// Denoise steps per chaos-sweep request.
const CHAOS_STEPS: usize = 6;
/// Chaos dispatch window: a wave of this many requests is dispatched,
/// then every responder resolved, before the next wave goes out — so
/// the driver observes progress (or its absence) while replicas flap.
const CHAOS_WINDOW: usize = 8;
/// Per-responder deadline before a request counts as stranded. Far
/// beyond any healthy completion; only a genuine hang trips it.
const CHAOS_DEADLINE: Duration = Duration::from_secs(30);

/// Gauge-sourced outcome of one chaos run. Everything comes from the
/// router's monotone gauges, never per-incarnation reports: a panicked
/// incarnation's `ServeStats` die with its thread, the gauges survive
/// every respawn.
struct ChaosOutcome {
    dispatched: u64,
    completed: u64,
    cache_hits: u64,
    shed: u64,
    forfeited: u64,
    restarts: u64,
    breaker_trips: u64,
    dead: u64,
    stranded: usize,
}

impl ChaosOutcome {
    /// The admission conservation law with its cache term.
    fn conserved(&self) -> bool {
        self.dispatched
            == self.completed + self.cache_hits + self.shed + self.forfeited
    }
}

/// Drive `requests` through a pool whose replicas relive `plan_spec`,
/// in waves of [`CHAOS_WINDOW`]. With `supervised`, a background
/// thread ticks a [`Supervisor`] until the run drains (stopped before
/// shutdown so no respawn races the teardown); without it, a panic is
/// terminal exactly as in an unsupervised production pool. Each
/// respawned engine compiles its schedule fresh from the plan, so a
/// flapping replica relives the same deterministic timeline.
fn run_chaos_pool(plan_spec: &str, supervised: bool, replicas: usize,
                  requests: usize, steps: usize, sup_cfg: SupervisorConfig)
                  -> ChaosOutcome {
    let plan = FaultPlan::parse(plan_spec).expect("fault plan");
    let rebalancer = (replicas > 1).then(|| Rebalancer::new(STEAL_WINDOW));
    let factories: Vec<RespawnFactory> = (0..replicas)
        .map(|i| {
            let plan = plan.clone();
            let f: RespawnFactory = Arc::new(move || {
                let mut s = spec();
                s.faults = plan.for_replica(i);
                Ok(Box::new(SimEngine::new(s)) as Box<dyn PoolEngine>)
            });
            f
        })
        .collect();
    let handles: Vec<ReplicaHandle> = factories
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let tier = match &rebalancer {
                Some(rb) => ReplicaTier {
                    steal_window: rb.admit_window(),
                    ..ReplicaTier::default()
                },
                None => ReplicaTier::default(),
            };
            if supervised {
                ReplicaHandle::spawn_supervised(i, 64, f, rebalancer.clone(),
                                                tier, Tracer::disabled(),
                                                None)
                    .unwrap()
            } else {
                let f = f.clone();
                ReplicaHandle::spawn_cached(i, 64, Box::new(move || f()),
                                            rebalancer.clone(), tier,
                                            Tracer::disabled(), None)
                    .unwrap()
            }
        })
        .collect();
    let router = Arc::new(Router::with_rebalancer(
        handles, RoutePolicy::Jsq, 64, rebalancer.clone()));
    let stop = Arc::new(AtomicBool::new(false));
    let ticker = supervised.then(|| {
        let mut sup = Supervisor::new(router.clone(), factories.clone(),
                                      rebalancer, None, sup_cfg);
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                sup.tick(epoch_us());
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    });
    let mut stranded = 0usize;
    let mut sent = 0usize;
    while sent < requests {
        let wave = CHAOS_WINDOW.min(requests - sent);
        let mut rxs = Vec::with_capacity(wave);
        for _ in 0..wave {
            let (tx, rx) = mpsc::channel();
            let req =
                Request::new(0, sent % 10, steps, 61_000 + sent as u64);
            if router.dispatch(req, tx) {
                rxs.push(rx);
            }
            sent += 1;
        }
        for rx in rxs {
            match rx.recv_timeout(CHAOS_DEADLINE) {
                // a response (even a failed one) or a dropped responder
                // (forfeit) both settle the request; only silence
                // strands
                Ok(_) | Err(mpsc::RecvTimeoutError::Disconnected) => {}
                Err(mpsc::RecvTimeoutError::Timeout) => stranded += 1,
            }
        }
    }
    stop.store(true, Ordering::Relaxed);
    if let Some(t) = ticker {
        t.join().expect("supervisor ticker");
    }
    router.shutdown();
    ChaosOutcome {
        dispatched: router.total_dispatched(),
        completed: router.total_completed(),
        cache_hits: router.total_cache_hits(),
        shed: router.shed_count(),
        forfeited: router.total_forfeited(),
        restarts: router.total_restarts(),
        breaker_trips: router.total_breaker_trips(),
        dead: router.dead_replicas() as u64,
        stranded,
    }
}

/// The chaos schedule sweep: every fault family — deterministic panic,
/// probabilistic panics at increasing rates, stall, queue-full burst,
/// snapshot corruption — through a supervised 2-replica stealing pool.
/// Under EVERY schedule the admission ledger balances exactly and no
/// request strands; deterministic panic schedules must also show
/// actual respawns. Returns the JSON rows plus total restarts and
/// breaker trips across the sweep.
fn chaos_schedule_sweep() -> (Json, u64, u64) {
    println!("chaos schedule sweep (supervised 2-replica steal pool, \
              {CHAOS_REQUESTS} req × {CHAOS_STEPS} steps, window \
              {CHAOS_WINDOW}):");
    let cfg = || SupervisorConfig {
        backoff_base_ms: 5,
        breaker_probe_ms: 20,
        breaker_close_after_ms: 40,
        ..SupervisorConfig::default()
    };
    // one schedule per fault family plus a fault-rate sweep: with both
    // replicas flapping at 30%/round the pool may burn its restart
    // budgets and die — the ledger must balance even then
    let schedules: &[(&str, &str, bool)] = &[
        ("panic", "panic@5,seed=3", true),
        ("panic-rate-5", "panic~5,r1:panic~5,seed=9", false),
        ("panic-rate-15", "panic~15,r1:panic~15,seed=11", false),
        ("panic-rate-30", "panic~30,r1:panic~30,seed=13", false),
        ("stall", "stall@3=150,r1:stall@5=100", false),
        ("burst", "burst@4=3,seed=5", false),
        ("corrupt", "corrupt@2,panic@7,seed=7", true),
    ];
    let mut rows = Vec::new();
    let (mut restarts, mut trips) = (0u64, 0u64);
    for (name, plan, deterministic_panic) in schedules {
        let o = run_chaos_pool(plan, true, 2, CHAOS_REQUESTS, CHAOS_STEPS,
                               cfg());
        assert!(o.conserved(),
                "chaos '{name}': dispatched {} != completed {} + hits {} \
                 + shed {} + forfeited {}",
                o.dispatched, o.completed, o.cache_hits, o.shed,
                o.forfeited);
        assert_eq!(o.stranded, 0,
                   "chaos '{name}': no responder may hang");
        assert_eq!(o.dispatched, CHAOS_REQUESTS as u64);
        if *deterministic_panic {
            assert!(o.restarts >= 1,
                    "chaos '{name}': a deterministic panic schedule must \
                     respawn at least once");
        }
        restarts += o.restarts;
        trips += o.breaker_trips;
        println!("  {:<14} completed {:>2}  shed {:>2}  forfeited {:>2}  \
                  restarts {}  trips {}  dead {}  ledger ok",
                 name, o.completed, o.shed, o.forfeited, o.restarts,
                 o.breaker_trips, o.dead);
        rows.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("plan", Json::str(plan)),
            ("dispatched", Json::num(o.dispatched as f64)),
            ("completed", Json::num(o.completed as f64)),
            ("shed", Json::num(o.shed as f64)),
            ("forfeited", Json::num(o.forfeited as f64)),
            ("restarts", Json::num(o.restarts as f64)),
            ("breaker_trips", Json::num(o.breaker_trips as f64)),
            ("dead", Json::num(o.dead as f64)),
            ("stranded", Json::num(o.stranded as f64)),
        ]));
    }
    (Json::arr(rows), restarts, trips)
}

/// Supervision A/B: the same deterministic panic schedule against the
/// same single-replica pool, with and without a supervisor. The
/// unsupervised pool dies at the panic — queued work forfeits, later
/// waves shed — while the supervised pool respawns into the same slot
/// (same queue identity, residents resumed from snapshots) and
/// finishes the whole workload. Strictly more completions is the
/// entire point of carrying a supervisor.
fn supervision_ab() -> Json {
    const AB_REQUESTS: usize = 24;
    const AB_STEPS: usize = 4;
    const AB_PLAN: &str = "panic@6,seed=1";
    println!("supervision A/B (1 replica, {AB_PLAN}, {AB_REQUESTS} req × \
              {AB_STEPS} steps):");
    // deep restart budget and breaker effectively disabled: with ONE
    // replica any open breaker or retired slot converts completions
    // into sheds, and this scenario isolates respawn — the sweep above
    // exercises the breaker with a sibling to absorb traffic
    let cfg = SupervisorConfig {
        restart_budget: 16,
        backoff_base_ms: 5,
        breaker_open_after: 1_000,
        ..SupervisorConfig::default()
    };
    let unsup = run_chaos_pool(AB_PLAN, false, 1, AB_REQUESTS, AB_STEPS,
                               SupervisorConfig::default());
    let sup = run_chaos_pool(AB_PLAN, true, 1, AB_REQUESTS, AB_STEPS, cfg);
    for (name, o) in [("unsupervised", &unsup), ("supervised", &sup)] {
        assert!(o.conserved(), "A/B {name}: ledger must balance");
        assert_eq!(o.stranded, 0, "A/B {name}: no responder may hang");
        println!("  {:<13} completed {:>2}/{AB_REQUESTS}  shed {:>2}  \
                  forfeited {:>2}  restarts {}",
                 name, o.completed, o.shed, o.forfeited, o.restarts);
    }
    assert!(sup.restarts >= 1, "the panic schedule must actually respawn");
    assert_eq!(sup.completed, AB_REQUESTS as u64,
               "a supervised pool must finish the whole workload through \
                repeated panics");
    assert!(sup.completed > unsup.completed,
            "supervision must strictly out-complete an unsupervised pool \
             under the identical panic schedule ({} vs {})",
            sup.completed, unsup.completed);
    Json::obj(vec![
        ("plan", Json::str(AB_PLAN)),
        ("requests", Json::num(AB_REQUESTS as f64)),
        ("supervised_completed", Json::num(sup.completed as f64)),
        ("unsupervised_completed", Json::num(unsup.completed as f64)),
        ("supervised_restarts", Json::num(sup.restarts as f64)),
    ])
}

// ----------------------------------------------------------- brownout

/// Requests per brownout stage point.
const BROWNOUT_REQUESTS: usize = 96;
/// Steps per brownout request: small, so the step-0 cold work that
/// stage 1's warm starts reclaim is a meaningful share of the total.
const BROWNOUT_STEPS: usize = 3;
/// Stage-3 best-effort step cap (must stay ≥ 2: a 1-step trajectory
/// retires at its first boundary and can never donate, which would
/// leave the capped family permanently cold).
const BROWNOUT_STEP_CAP: usize = 2;
/// Stage-2 Γ boost in percentage points.
const BROWNOUT_GAMMA_BOOST: u32 = 15;
/// Admission bound for the sweep: small enough that overload sheds
/// instead of queueing unboundedly.
const BROWNOUT_QUEUE_CAP: usize = 6;
/// Work per executed module — heavier than the chaos runs so the
/// arrival pacer's sleep/spin granularity sits well under the service
/// time.
const BROWNOUT_WORK: u64 = 200_000;
/// Offered load as a multiple of the measured stage-0 service rate.
/// The skip gate is a pure (step, slot) hash, so per-request executed
/// modules are exact constants per stage — 18, 12, 6, 5 at Γ=50% +15
/// boost — and 4.5× keeps every stage's shed count strictly interior
/// (neither saturated at the queue bound nor clipped at zero).
const BROWNOUT_OVERLOAD: f64 = 4.5;

fn brownout_spec() -> SimSpec {
    SimSpec { lazy_pct: LAZY_PCT, work_per_module: BROWNOUT_WORK,
              ..SimSpec::default() }
}

fn brownout_cfg() -> BrownoutConfig {
    BrownoutConfig {
        horizon_widen: 7,
        gamma_boost: BROWNOUT_GAMMA_BOOST,
        besteffort_step_cap: BROWNOUT_STEP_CAP,
        ..BrownoutConfig::default()
    }
}

/// Stage-0 service time per request: a small closed-loop probe on the
/// sweep's exact replica shape, the base the overload factor divides.
fn calibrate_brownout_pace() -> Duration {
    let probe = 8usize;
    let h = ReplicaHandle::spawn_cached(
        0, probe, SimEngine::factory(brownout_spec()), None,
        ReplicaTier::new(Slo::Besteffort, 4), Tracer::disabled(), None)
        .unwrap();
    let router = Router::new(vec![h], RoutePolicy::Jsq, probe);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..probe {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(
            Request::new(0, i % 2, BROWNOUT_STEPS, 30_000 + i as u64), tx));
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().expect("probe response");
    }
    let per_req = t0.elapsed() / probe as u32;
    router.shutdown();
    per_req
}

/// One open-loop pass at a forced brownout stage. Seeds are unique so
/// the exact tier can never hit — everything below stage 1 is honest
/// compute — and arrivals are paced by the wall clock (the same
/// sleep/spin idiom as [`run_open_loop`]), never by completions.
/// Returns (shed, completed).
fn run_brownout_stage(stage: usize, pace: Duration) -> (u64, u64) {
    let cache = Arc::new(PoolCache::new(CacheConfig::new(
        256, 0, 0xB10C + stage as u64)));
    let h = ReplicaHandle::spawn_cached(
        0, BROWNOUT_QUEUE_CAP, SimEngine::factory(brownout_spec()), None,
        ReplicaTier::new(Slo::Besteffort, 4), Tracer::disabled(),
        Some(cache.clone()))
        .unwrap();
    let b = Arc::new(Brownout::new(brownout_cfg(), Some(cache.clone())));
    let router = Router::with_cache(vec![h], RoutePolicy::Jsq,
                                    BROWNOUT_QUEUE_CAP, None, Some(cache))
        .with_brownout_controller(b.clone());
    b.force_stage(stage, &router);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..BROWNOUT_REQUESTS {
        let target = pace.as_secs_f64() * i as f64;
        loop {
            let remain = target - t0.elapsed().as_secs_f64();
            if remain <= 0.0 {
                break;
            }
            if remain > 1e-3 {
                std::thread::sleep(Duration::from_secs_f64(remain - 5e-4));
            } else {
                std::hint::spin_loop();
            }
        }
        let (tx, rx) = mpsc::channel();
        let req =
            Request::new(0, i % 2, BROWNOUT_STEPS, 100_000 + i as u64);
        if router.dispatch(req, tx) {
            rxs.push(rx);
        }
    }
    let mut stranded = 0usize;
    for rx in rxs {
        if rx.recv_timeout(CHAOS_DEADLINE).is_err() {
            stranded += 1;
        }
    }
    assert_eq!(stranded, 0,
               "brownout stage {stage}: every admitted request resolves");
    let (dispatched, completed, hits, shed, forfeited) = (
        router.total_dispatched(), router.total_completed(),
        router.total_cache_hits(), router.shed_count(),
        router.total_forfeited());
    assert_eq!(dispatched, completed + hits + shed + forfeited,
               "brownout stage {stage}: ledger must balance");
    assert_eq!(dispatched, BROWNOUT_REQUESTS as u64);
    assert_eq!(forfeited, 0, "no faults here — nothing may forfeit");
    router.shutdown();
    (shed, completed)
}

/// The brownout ladder under sustained overload: force each stage and
/// measure the shed rate at identical offered load. Every dial buys
/// real capacity — warm starts reclaim step-0 cold work, the Γ boost
/// skips more rows, the step cap shortens best-effort schedules — so
/// the shed rate must fall STRICTLY at every stage. Returns the
/// `brownout` rows of the chaos section.
fn brownout_shed_sweep() -> Json {
    let per_req = calibrate_brownout_pace();
    let pace = per_req.div_f64(BROWNOUT_OVERLOAD);
    println!("brownout shed sweep ({BROWNOUT_REQUESTS} req × \
              {BROWNOUT_STEPS} steps, queue cap {BROWNOUT_QUEUE_CAP}, \
              offered {BROWNOUT_OVERLOAD:.1}× stage-0 capacity, service \
              ≈ {:.2}ms/req):",
             1e3 * per_req.as_secs_f64());
    let mut rows = Vec::new();
    let mut last_shed = 0u64;
    for stage in 0..=3usize {
        let (shed, completed) = run_brownout_stage(stage, pace);
        let rate = shed as f64 / BROWNOUT_REQUESTS as f64;
        println!("  stage {stage}: shed {:>2}/{BROWNOUT_REQUESTS} \
                  ({:>4.1}%)  completed {:>2}",
                 shed, 100.0 * rate, completed);
        if stage == 0 {
            assert!(shed > 0,
                    "the sweep must actually overload the undegraded \
                     pool, or the ladder has nothing to relieve");
        } else {
            assert!(shed < last_shed,
                    "brownout stage {stage} must shed strictly less than \
                     stage {} ({shed} vs {last_shed}) — every degradation \
                     dial must buy real capacity",
                    stage - 1);
        }
        last_shed = shed;
        rows.push(Json::obj(vec![
            ("stage", Json::num(stage as f64)),
            ("shed", Json::num(shed as f64)),
            ("shed_rate", Json::num(rate)),
            ("completed", Json::num(completed as f64)),
        ]));
    }
    Json::arr(rows)
}

// ----------------------------------------------------------- deadline

/// Requests per deadline A/B cell (one arm at one offered load).
const DEADLINE_REQUESTS: usize = 48;
/// Steps per deadline request.
const DEADLINE_STEPS: usize = 4;
/// Work per executed module — heavy like the brownout sweep, so the
/// per-request service time dominates the arrival pacer's sleep/spin
/// granularity and a CI scheduling hiccup stays well inside the
/// tight-class slack.
const DEADLINE_WORK: u64 = 200_000;
/// Queue bound: deep enough that nothing sheds for capacity — every
/// shed in the EDF arm is a priced no-slack shed, and the FIFO arm
/// must never shed at all.
const DEADLINE_QUEUE_CAP: usize = 64;
/// Tight-class relative deadline, in calibrated service times.
const DEADLINE_TIGHT_X: f64 = 8.0;
/// Loose-class relative deadline, in calibrated service times. Chosen
/// so that at 2× offered load FIFO's linearly growing queue wait
/// overruns it for the back half of the trace — capacity FIFO then
/// wastes finishing already-doomed work, which is exactly what the
/// no-slack shed reclaims.
const DEADLINE_LOOSE_X: f64 = 16.0;

fn deadline_spec() -> SimSpec {
    SimSpec { lazy_pct: LAZY_PCT, work_per_module: DEADLINE_WORK,
              ..SimSpec::default() }
}

/// Profile a skip calendar for the deadline pool the same way `lazydit
/// calibrate --synthetic` does: drain a seeded trace through a fresh
/// simulator and fold its per-step run/seen counters into one entry.
fn deadline_calendar() -> SkipCalendar {
    let mut engine = SimEngine::new(deadline_spec());
    let requests = 8u64;
    for i in 0..requests {
        let mut req =
            Request::new(0, (i % 10) as usize, DEADLINE_STEPS, 70_000 + i);
        req.cfg_scale = 1.0;
        engine.submit(req);
    }
    while engine.active_count() > 0 {
        engine.step_round().expect("calibration round");
    }
    let mut cal = SkipCalendar::new(0xD11E, "sim");
    cal.insert_profile(DEADLINE_STEPS,
                       engine.step_profile()
                           .expect("the simulator profiles steps"),
                       requests);
    cal
}

/// Per-request service time on the deadline pool's exact B1 replica
/// shape — the unit the offered loads and relative deadlines scale.
fn calibrate_deadline_pace() -> Duration {
    let probe = 8usize;
    let h = ReplicaHandle::spawn_cached(
        0, DEADLINE_QUEUE_CAP, SimEngine::factory(deadline_spec()), None,
        ReplicaTier::new(Slo::Besteffort, 1), Tracer::disabled(), None)
        .unwrap();
    let router = Router::new(vec![h], RoutePolicy::Jsq, DEADLINE_QUEUE_CAP);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..probe {
        let (tx, rx) = mpsc::channel();
        let mut req =
            Request::new(0, i % 10, DEADLINE_STEPS, 71_000 + i as u64);
        req.cfg_scale = 1.0;
        assert!(router.dispatch(req, tx), "pace probe must admit");
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().expect("probe response");
    }
    let per_req = t0.elapsed() / probe as u32;
    router.shutdown();
    per_req
}

/// Client-observed outcome of one deadline arm: index 0 is the tight
/// class, index 1 the loose class. A hit is a response that arrived on
/// the client thread before the request's absolute deadline; sheds and
/// late completions are both misses — nothing is scored server-side.
struct DeadlineArm {
    offered: [usize; 2],
    hits: [usize; 2],
    slack_sheds: u64,
}

impl DeadlineArm {
    fn total_hits(&self) -> usize {
        self.hits[0] + self.hits[1]
    }
}

/// One open-loop pass at `load`× the calibrated capacity, alternating
/// tight/loose deadlines. `oracle` arms the EDF + calendar-pricing
/// stack (the FIFO baseline passes `None` and runs the legacy path:
/// arrival order, no pricing, no shed).
fn run_deadline_arm(edf: bool, oracle: Option<&Arc<PoolCalendar>>,
                    svc: Duration, load: f64) -> DeadlineArm {
    let tier = ReplicaTier { edf, ..ReplicaTier::new(Slo::Besteffort, 1) };
    let h = ReplicaHandle::spawn_cached(
        0, DEADLINE_QUEUE_CAP, SimEngine::factory(deadline_spec()), None,
        tier, Tracer::disabled(), None)
        .unwrap();
    let mut router =
        Router::new(vec![h], RoutePolicy::Jsq, DEADLINE_QUEUE_CAP);
    if let Some(c) = oracle {
        router = router.with_calendar(c.clone());
    }
    let svc_us = svc.as_secs_f64() * 1e6;
    let rels = [(svc_us * DEADLINE_TIGHT_X) as u64,
                (svc_us * DEADLINE_LOOSE_X) as u64];
    let pace = svc.div_f64(load);
    let t0 = Instant::now();
    let mut offered = [0usize; 2];
    let mut joins = Vec::with_capacity(DEADLINE_REQUESTS);
    for i in 0..DEADLINE_REQUESTS {
        // wall-clock pacing, never completion-paced (the same
        // anti-coordinated-omission idiom as run_open_loop)
        let target = pace.as_secs_f64() * i as f64;
        loop {
            let remain = target - t0.elapsed().as_secs_f64();
            if remain <= 0.0 {
                break;
            }
            if remain > 1e-3 {
                std::thread::sleep(Duration::from_secs_f64(remain - 5e-4));
            } else {
                std::hint::spin_loop();
            }
        }
        let class = i % 2; // 0 = tight, 1 = loose
        let mut req =
            Request::new(0, i % 10, DEADLINE_STEPS, 72_000 + i as u64);
        req.cfg_scale = 1.0;
        req.deadline_us = epoch_us() + rels[class];
        let deadline = req.deadline_us;
        offered[class] += 1;
        let (tx, rx) = mpsc::channel();
        if router.dispatch(req, tx) {
            joins.push(std::thread::spawn(move || {
                let ok = rx.recv().is_ok() && epoch_us() <= deadline;
                (class, ok)
            }));
        }
        // a shed request simply never hits — a client-side miss
    }
    let mut hits = [0usize; 2];
    for j in joins {
        let (class, ok) = j.join().expect("collector");
        if ok {
            hits[class] += 1;
        }
    }
    let (dispatched, completed, cache_hits, shed, forfeited, slack) = (
        router.total_dispatched(), router.total_completed(),
        router.total_cache_hits(), router.shed_count(),
        router.total_forfeited(), router.slack_shed_count());
    assert_eq!(dispatched, completed + cache_hits + shed + forfeited,
               "deadline arm: ledger must balance");
    assert!(slack <= shed,
            "slack sheds attribute a reason inside the shed term, never \
             beside it");
    if oracle.is_none() {
        assert_eq!(shed, 0,
                   "the FIFO arm has no pricing and a deep queue — \
                    nothing may shed");
    }
    router.shutdown();
    DeadlineArm { offered, hits, slack_sheds: slack }
}

/// The deadline A/B: EDF + calendar pricing against FIFO + no pricing
/// at 0.5×/1×/2× offered load. EDF must never lose, and at 2× it must
/// win strictly: FIFO burns saturated-server capacity completing
/// requests that already missed, while the priced no-slack shed turns
/// that work into on-time completions. Returns the `deadline` section
/// of `BENCH_serve.json`.
fn deadline_sweep() -> Json {
    let cal = deadline_calendar();
    let cost = cal.cost_from(DEADLINE_STEPS, 0).expect("profiled entry");
    let svc = calibrate_deadline_pace();
    let oracle = Arc::new(PoolCalendar::new(Some(cal)));
    // μs per module invocation from the probe: the calendar then prices
    // one request at exactly the measured per-request service time
    oracle.set_us_per_inv(svc.as_secs_f64() * 1e6 / cost.max(1e-9));
    println!("deadline A/B (EDF + calendar pricing vs FIFO, B1 replica, \
              {DEADLINE_REQUESTS} req × {DEADLINE_STEPS} steps, tight \
              {DEADLINE_TIGHT_X:.0}×svc / loose {DEADLINE_LOOSE_X:.0}×svc, \
              svc ≈ {:.2}ms, {cost:.1} rows/req):",
             1e3 * svc.as_secs_f64());
    let mut points = Vec::new();
    for load in [0.5, 1.0, 2.0] {
        let fifo = run_deadline_arm(false, None, svc, load);
        let edf = run_deadline_arm(true, Some(&oracle), svc, load);
        for (name, arm) in [("fifo", &fifo), ("edf", &edf)] {
            println!("  {:>4.1}×c {:<5} hit {:>2}/{} (tight {:>2}/{}, \
                      loose {:>2}/{})  slack-shed {:>2}",
                     load, name, arm.total_hits(), DEADLINE_REQUESTS,
                     arm.hits[0], arm.offered[0], arm.hits[1],
                     arm.offered[1], arm.slack_sheds);
            let rate = |h: usize, n: usize| h as f64 / n.max(1) as f64;
            points.push(Json::obj(vec![
                ("arm", Json::str(name)),
                ("load_x", Json::num(load)),
                ("offered", Json::num(DEADLINE_REQUESTS as f64)),
                ("hit_rate",
                 Json::num(rate(arm.total_hits(), DEADLINE_REQUESTS))),
                ("tight_hit_rate",
                 Json::num(rate(arm.hits[0], arm.offered[0]))),
                ("loose_hit_rate",
                 Json::num(rate(arm.hits[1], arm.offered[1]))),
                ("slack_sheds", Json::num(arm.slack_sheds as f64)),
            ]));
        }
        assert!(edf.total_hits() >= fifo.total_hits(),
                "EDF + pricing must never lose to FIFO ({} vs {} hits \
                 at {load}× load)",
                edf.total_hits(), fifo.total_hits());
        if load >= 2.0 {
            assert!(edf.total_hits() > fifo.total_hits(),
                    "at 2× offered load EDF + pricing must beat FIFO \
                     strictly ({} vs {} hits)",
                    edf.total_hits(), fifo.total_hits());
            assert!(edf.slack_sheds > 0,
                    "sustained overload must actually engage the \
                     no-slack shed");
        }
    }
    Json::obj(vec![
        ("tight_x", Json::num(DEADLINE_TIGHT_X)),
        ("loose_x", Json::num(DEADLINE_LOOSE_X)),
        ("service_ms", Json::num(1e3 * svc.as_secs_f64())),
        ("points", Json::arr(points)),
    ])
}

// ---------------------------------------------------------- open loop

/// Requests per open-loop point (per route × offered-load cell).
const OPEN_REQUESTS: usize = 96;
/// Pool-wide admission bound for the open-loop runs: small enough that
/// overload actually sheds instead of queueing unboundedly.
const OPEN_QUEUE_CAP: usize = 12;

/// The mixed-tier pool under test: one latency-tuned B1 replica and
/// three throughput-tuned B8 replicas, all at the same Γ target.
fn open_loop_tiers() -> Vec<ReplicaTier> {
    vec![
        ReplicaTier::new(Slo::Latency, 1),
        ReplicaTier::new(Slo::Throughput, 8),
        ReplicaTier::new(Slo::Throughput, 8),
        ReplicaTier::new(Slo::Throughput, 8),
    ]
}

fn build_tiered_router(route: RoutePolicy) -> Router {
    let handles: Vec<ReplicaHandle> = open_loop_tiers()
        .into_iter()
        .enumerate()
        .map(|(i, tier)| {
            ReplicaHandle::spawn_tiered(i, OPEN_QUEUE_CAP,
                                        SimEngine::factory(spec()), None,
                                        tier)
            .unwrap()
        })
        .collect();
    Router::new(handles, route, OPEN_QUEUE_CAP)
}

/// Per-tier outcome of one open-loop run.
struct TierOutcome {
    offered: usize,
    shed: usize,
    hist: LatencyHist,
}

/// Replay one Poisson trace open-loop at `rate` req/s. Arrivals are
/// paced by the trace clock — never by completions — so queueing delay
/// lands in the latency numbers instead of silently throttling the
/// offered load (the coordinated-omission trap of closed-loop floods).
fn run_open_loop(route: RoutePolicy, rate: f64) -> [TierOutcome; 3] {
    let router = build_tiered_router(route);
    let trace = WorkloadSpec {
        requests: OPEN_REQUESTS,
        rate,
        steps_choices: vec![STEPS],
        num_classes: 10,
        seed: 42,
        slo_mix: vec![(Slo::Latency, 0.3), (Slo::Throughput, 0.5),
                      (Slo::Besteffort, 0.2)],
    }
    .generate();
    let t0 = Instant::now();
    let mut offered = [0usize; 3];
    let mut shed = [0usize; 3];
    let mut joins = Vec::with_capacity(OPEN_REQUESTS);
    for ev in &trace.events {
        // open loop: wait for the scheduled arrival, not for completions.
        // Sleep the bulk of the gap (a core pinned at 100% would contend
        // with the very replica threads whose latency we measure) and
        // spin only the last stretch for sub-ms arrival precision.
        loop {
            let remain = ev.at - t0.elapsed().as_secs_f64();
            if remain <= 0.0 {
                break;
            }
            if remain > 1e-3 {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    remain - 5e-4));
            } else {
                std::hint::spin_loop();
            }
        }
        offered[ev.slo.index()] += 1;
        let mut req = Request::new(0, ev.class_label, ev.steps, ev.seed)
            .with_slo(ev.slo);
        if ev.slo == Slo::Latency {
            // latency clients run guidance-free: a 2-lane CFG request
            // cannot fit the B1 latency tier (the router would shed it)
            req.cfg_scale = 1.0;
        }
        let (tx, rx) = mpsc::channel();
        let sent_at = t0.elapsed().as_secs_f64();
        if router.dispatch(req, tx) {
            let slo = ev.slo;
            joins.push(std::thread::spawn(move || {
                rx.recv().expect("response");
                (slo, t0.elapsed().as_secs_f64() - sent_at)
            }));
        } else {
            shed[ev.slo.index()] += 1;
        }
    }
    let hists: [LatencyHist; 3] = Default::default();
    for j in joins {
        let (slo, lat) = j.join().expect("collector");
        hists[slo.index()].record_secs(lat);
    }
    let report = router.shutdown();
    let total_shed: usize = shed.iter().sum();
    assert_eq!(report.completed() + total_shed, OPEN_REQUESTS,
               "open loop: every request completes or sheds, exactly once");
    assert_eq!(report.shed_by_slo.iter().sum::<u64>(), total_shed as u64,
               "per-tier shed counters agree with the dispatcher");
    let mut out: Vec<TierOutcome> = Vec::with_capacity(3);
    for slo in Slo::ALL {
        let i = slo.index();
        out.push(TierOutcome {
            offered: offered[i],
            shed: shed[i],
            hist: hists[i].clone(),
        });
    }
    out.try_into().map_err(|_| "three tiers").unwrap()
}

/// Estimate the tiered pool's capacity (req/s): serve a small
/// closed-loop batch through one replica and scale by the pool size.
fn calibrate_capacity() -> f64 {
    let probe = 16usize;
    let h = ReplicaHandle::spawn_tiered(
        0, probe.max(1), SimEngine::factory(spec()), None,
        ReplicaTier::new(Slo::Besteffort, 8))
        .unwrap();
    let router = Router::new(vec![h], RoutePolicy::Jsq, probe);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..probe {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(
            Request::new(0, i % 10, STEPS, 40_000 + i as u64), tx));
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().expect("probe response");
    }
    let per_req = t0.elapsed().as_secs_f64() / probe as f64;
    router.shutdown();
    open_loop_tiers().len() as f64 / per_req.max(1e-9)
}

/// Run the sweep, print the table, and return one JSON point per
/// (route × load × tier) cell with histogram-backed p50/p95/p99 — the
/// `open_loop` array of `BENCH_serve.json`.
fn open_loop_sweep() -> Json {
    let cap = calibrate_capacity();
    println!(
        "open-loop Poisson sweep (pool lat:b1x1 + thr:b8x3, queue cap \
         {OPEN_QUEUE_CAP}, {OPEN_REQUESTS} req/point; measured capacity \
         ≈ {cap:.0} req/s):"
    );
    println!(
        "  {:<6} {:>9}  {:<11} {:>7} {:>7} {:>10} {:>10}",
        "route", "offered", "tier", "req", "shed%", "p50", "p95"
    );
    let mut points: Vec<Json> = Vec::new();
    for route in [RoutePolicy::Jsq, RoutePolicy::Lazy] {
        for load in [0.5, 1.0, 2.0] {
            let rate = (cap * load).max(1.0);
            let tiers = run_open_loop(route, rate);
            for (slo, t) in Slo::ALL.iter().zip(tiers.iter()) {
                let shed_pct = if t.offered == 0 {
                    0.0
                } else {
                    100.0 * t.shed as f64 / t.offered as f64
                };
                println!(
                    "  {:<6} {:>7.2}×c  {:<11} {:>7} {:>6.1}% {:>8.2}ms \
                     {:>8.2}ms",
                    route.name(),
                    load,
                    slo.name(),
                    t.offered,
                    shed_pct,
                    t.hist.quantile_ms(0.5),
                    t.hist.quantile_ms(0.95),
                );
                points.push(Json::obj(vec![
                    ("route", Json::str(route.name())),
                    ("load_x", Json::num(load)),
                    ("tier", Json::str(slo.name())),
                    ("offered", Json::num(t.offered as f64)),
                    ("shed_pct", Json::num(shed_pct)),
                    ("p50_ms", Json::num(t.hist.quantile_ms(0.50))),
                    ("p95_ms", Json::num(t.hist.quantile_ms(0.95))),
                    ("p99_ms", Json::num(t.hist.quantile_ms(0.99))),
                ]));
            }
        }
    }
    println!(
        "  (open loop: arrivals are paced by the trace, not completions — \
         p95 includes queue wait; shed% is admission-control drops)"
    );
    Json::arr(points)
}

fn main() {
    lazydit::util::logging::init();
    // BENCH_SMOKE=1: the tier-1 gate runs only the (fast, fully
    // asserted) cache scenario and still writes the `cache` section the
    // smoke grep checks; the full sweep overwrites the file in CI.
    let smoke =
        std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        let cache = cache_scenario();
        let json = Json::obj(vec![
            ("bench", Json::str("pool_scaling")),
            ("smoke", Json::Bool(true)),
            ("cache", cache),
        ]);
        std::fs::write("BENCH_serve.json", format!("{json}\n"))
            .expect("write BENCH_serve.json");
        println!("wrote BENCH_serve.json (smoke: cache scenario only)");
        return;
    }
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let mut sweep: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&n| n <= cores.max(2)).collect();
    if sweep.is_empty() {
        sweep.push(1);
    }

    // reference checksums straight from the deterministic image function
    let elems = spec().img_elems;
    let mut reference: Vec<u64> = workload()
        .iter()
        .map(|req| fnv64(sim_image(req, elems).data()))
        .collect();
    reference.sort_unstable();

    println!(
        "pool_scaling: {REQUESTS} requests × {STEPS} steps, Γ target \
         {LAZY_PCT}%, work/module {WORK} ({cores} cores)\n"
    );
    println!("replica sweep (route jsq):");
    let mut base_rps = 0.0f64;
    let mut widest_rps = 0.0f64;
    let mut deterministic = true;
    for &n in &sweep {
        let r = run_pool(n, RoutePolicy::Jsq);
        println!("{}", row(&format!("{n} replica(s)"), &r));
        deterministic &= r.checksums == reference;
        let rps = REQUESTS as f64 / r.wall_s;
        if n == 1 {
            base_rps = rps;
        }
        widest_rps = rps;
    }

    let widest = *sweep.last().unwrap();
    println!("\nrouting policies at {widest} replica(s):");
    for route in [RoutePolicy::RoundRobin, RoutePolicy::Jsq, RoutePolicy::Lazy] {
        let r = run_pool(widest, route);
        println!("{}", row(route.name(), &r));
        deterministic &= r.checksums == reference;
    }

    println!("\nwork stealing at {widest} replica(s) (uniform Γ):");
    for steal in [false, true] {
        let r = run_pool_with(vec![spec(); widest], RoutePolicy::Jsq, steal,
                              false);
        println!("{}", row(if steal { "jsq + steal" } else { "jsq" }, &r));
        deterministic &= r.checksums == reference;
    }

    // telemetry-ring overhead: the same closed-loop flood with every
    // replica recording trace events vs none. Advisory (wall-clock on a
    // shared machine is noisy) — the delta lands in BENCH_serve.json.
    println!("\ntrace overhead at {widest} replica(s) (ring {TRACE_RING} \
              events/replica):");
    let untraced =
        run_pool_with(vec![spec(); widest], RoutePolicy::Jsq, false, false);
    let traced =
        run_pool_with(vec![spec(); widest], RoutePolicy::Jsq, false, true);
    println!("{}", row("untraced", &untraced));
    println!("{}", row("traced", &traced));
    deterministic &= untraced.checksums == reference;
    deterministic &= traced.checksums == reference;
    let rps_untraced = REQUESTS as f64 / untraced.wall_s;
    let rps_traced = REQUESTS as f64 / traced.wall_s;
    let trace_overhead_pct =
        100.0 * (rps_untraced - rps_traced) / rps_untraced.max(1e-9);
    println!("  tracing cost: {trace_overhead_pct:+.1}% throughput");

    println!();
    let (p95_base, p95_steal) = skewed_gamma_scenario();

    println!();
    let migration = retag_scenario();

    println!();
    let cache = cache_scenario();

    println!();
    let (chaos_rows, chaos_restarts, chaos_trips) = chaos_schedule_sweep();

    println!();
    let supervision = supervision_ab();

    println!();
    let brownout = brownout_shed_sweep();
    let chaos = Json::obj(vec![
        ("schedules", chaos_rows),
        ("restarts", Json::num(chaos_restarts as f64)),
        ("breaker_trips", Json::num(chaos_trips as f64)),
        ("supervision", supervision),
        ("brownout", brownout),
    ]);

    println!();
    let open_loop_points = open_loop_sweep();

    println!();
    let deadline = deadline_sweep();

    println!();
    if deterministic {
        println!("determinism: OK — image bytes identical across every pool \
                  shape, routing policy, and steal mode");
    } else {
        println!("determinism: FAILED — outputs diverged across runs");
    }
    if widest > 1 {
        let speedup = widest_rps / base_rps.max(1e-9);
        println!("scaling: {widest} replicas at {speedup:.2}× the 1-replica \
                  throughput{}",
                 if speedup > 1.2 { " — OK" } else { " — WEAK (loaded machine?)" });
    }
    println!(
        "stealing under skewed Γ: p95 {:.2}ms → {:.2}ms{}",
        1e3 * p95_base,
        1e3 * p95_steal,
        if p95_steal < p95_base {
            " — OK (strictly lower)"
        } else {
            " — WEAK (expected stealing to beat static jsq; loaded machine?)"
        }
    );

    // serving perf trajectory: per-tier histogram quantiles + the
    // telemetry overhead delta (docs/OBSERVABILITY.md explains the keys)
    let json = Json::obj(vec![
        ("bench", Json::str("pool_scaling")),
        ("requests", Json::num(REQUESTS as f64)),
        ("steps", Json::num(STEPS as f64)),
        ("work_per_module", Json::num(WORK as f64)),
        ("open_loop", open_loop_points),
        ("deadline", deadline),
        ("migration", migration),
        ("cache", cache),
        ("chaos", chaos),
        ("trace_overhead", Json::obj(vec![
            ("replicas", Json::num(widest as f64)),
            ("ring_events", Json::num(TRACE_RING as f64)),
            ("untraced_rps", Json::num(rps_untraced)),
            ("traced_rps", Json::num(rps_traced)),
            ("overhead_pct", Json::num(trace_overhead_pct)),
        ])),
        ("skewed_gamma_p95_ms", Json::obj(vec![
            ("jsq", Json::num(1e3 * p95_base)),
            ("jsq_steal", Json::num(1e3 * p95_steal)),
        ])),
    ]);
    std::fs::write("BENCH_serve.json", format!("{json}\n"))
        .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
    if !deterministic {
        std::process::exit(1);
    }
}
