//! Bench: replica-pool scaling on the synthetic workload.
//!
//! Sweeps the pool 1→N replicas (closed-loop flood of the same request
//! set), reporting requests/sec and latency p50/p99 per point, then
//! compares routing policies at the widest pool. Also verifies the
//! determinism contract: result images are byte-identical to the
//! single-replica reference for every (seed, label, steps).
//!
//!     cargo bench --bench pool_scaling
//! (or `cargo run --release --bench pool_scaling` on toolchains where
//! bench profiles are unavailable)

use lazydit::config::RoutePolicy;
use lazydit::coordinator::pool::replica::ReplicaHandle;
use lazydit::coordinator::pool::sim::{sim_image, SimEngine, SimSpec};
use lazydit::coordinator::pool::Router;
use lazydit::coordinator::request::Request;
use lazydit::metrics::stats::quantile;
use std::sync::mpsc;
use std::time::Instant;

const REQUESTS: usize = 64;
const STEPS: usize = 10;
const WORK: u64 = 20_000;
const LAZY_PCT: u32 = 50;

fn spec() -> SimSpec {
    SimSpec { lazy_pct: LAZY_PCT, work_per_module: WORK, ..SimSpec::default() }
}

fn workload() -> Vec<Request> {
    (0..REQUESTS)
        .map(|i| Request::new(0, i % 10, STEPS, 7_000 + i as u64))
        .collect()
}

fn fnv64(data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in data {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

struct RunResult {
    wall_s: f64,
    latencies: Vec<f64>,
    checksums: Vec<u64>,
    shed: u64,
}

fn run_pool(replicas: usize, route: RoutePolicy) -> RunResult {
    let handles: Vec<ReplicaHandle> = (0..replicas)
        .map(|i| ReplicaHandle::spawn(i, 4096, SimEngine::factory(spec())).unwrap())
        .collect();
    let router = Router::new(handles, route, 4096);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(REQUESTS);
    for req in workload() {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(req, tx), "closed-loop run must not shed");
        rxs.push(rx);
    }
    let mut latencies = Vec::with_capacity(REQUESTS);
    let mut checksums = Vec::with_capacity(REQUESTS);
    for rx in rxs {
        let res = rx.recv().expect("response");
        latencies.push(res.latency.as_secs_f64());
        checksums.push(fnv64(res.image.data()));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = router.shutdown();
    checksums.sort_unstable();
    RunResult { wall_s, latencies, checksums, shed: report.shed }
}

fn row(label: &str, r: &RunResult) -> String {
    format!(
        "  {:<16} {:>9.1} req/s   p50 {:>8.2}ms   p99 {:>8.2}ms   ({} shed)",
        label,
        REQUESTS as f64 / r.wall_s,
        1e3 * quantile(&r.latencies, 0.5),
        1e3 * quantile(&r.latencies, 0.99),
        r.shed,
    )
}

fn main() {
    lazydit::util::logging::init();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let mut sweep: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&n| n <= cores.max(2)).collect();
    if sweep.is_empty() {
        sweep.push(1);
    }

    // reference checksums straight from the deterministic image function
    let elems = spec().img_elems;
    let mut reference: Vec<u64> = workload()
        .iter()
        .map(|req| fnv64(sim_image(req, elems).data()))
        .collect();
    reference.sort_unstable();

    println!(
        "pool_scaling: {REQUESTS} requests × {STEPS} steps, Γ target \
         {LAZY_PCT}%, work/module {WORK} ({cores} cores)\n"
    );
    println!("replica sweep (route jsq):");
    let mut base_rps = 0.0f64;
    let mut widest_rps = 0.0f64;
    let mut deterministic = true;
    for &n in &sweep {
        let r = run_pool(n, RoutePolicy::Jsq);
        println!("{}", row(&format!("{n} replica(s)"), &r));
        deterministic &= r.checksums == reference;
        let rps = REQUESTS as f64 / r.wall_s;
        if n == 1 {
            base_rps = rps;
        }
        widest_rps = rps;
    }

    let widest = *sweep.last().unwrap();
    println!("\nrouting policies at {widest} replica(s):");
    for route in [RoutePolicy::RoundRobin, RoutePolicy::Jsq, RoutePolicy::Lazy] {
        let r = run_pool(widest, route);
        println!("{}", row(route.name(), &r));
        deterministic &= r.checksums == reference;
    }

    println!();
    if deterministic {
        println!("determinism: OK — image bytes identical across every pool \
                  shape and routing policy");
    } else {
        println!("determinism: FAILED — outputs diverged across runs");
    }
    if widest > 1 {
        let speedup = widest_rps / base_rps.max(1e-9);
        println!("scaling: {widest} replicas at {speedup:.2}× the 1-replica \
                  throughput{}",
                 if speedup > 1.2 { " — OK" } else { " — WEAK (loaded machine?)" });
    }
    if !deterministic {
        std::process::exit(1);
    }
}
