//! Bench: replica-pool scaling on the synthetic workload.
//!
//! Sweeps the pool 1→N replicas (closed-loop flood of the same request
//! set), reporting requests/sec and latency p50/p99 per point, then
//! compares routing policies at the widest pool, then runs the skewed-Γ
//! scenario: replicas whose lazy ratios diverge, where admission-time
//! jsq placement strands work on the slow (never-skipping) replica and
//! work stealing pulls it back. Also verifies the determinism contract:
//! result images are byte-identical to the single-replica reference for
//! every (seed, label, steps).
//!
//!     cargo bench --bench pool_scaling
//! (or `cargo run --release --bench pool_scaling` on toolchains where
//! bench profiles are unavailable)

use lazydit::config::RoutePolicy;
use lazydit::coordinator::pool::replica::ReplicaHandle;
use lazydit::coordinator::pool::sim::{sim_image, SimEngine, SimSpec};
use lazydit::coordinator::pool::steal::Rebalancer;
use lazydit::coordinator::pool::{PoolReport, Router};
use lazydit::coordinator::request::Request;
use lazydit::metrics::stats::quantile;
use std::sync::mpsc;
use std::time::Instant;

const REQUESTS: usize = 64;
const STEPS: usize = 10;
const WORK: u64 = 20_000;
const LAZY_PCT: u32 = 50;
/// In-engine admission bound while stealing (jobs beyond it stay
/// queued, i.e. migratable).
const STEAL_WINDOW: usize = 2;

fn spec() -> SimSpec {
    SimSpec { lazy_pct: LAZY_PCT, work_per_module: WORK, ..SimSpec::default() }
}

fn workload() -> Vec<Request> {
    (0..REQUESTS)
        .map(|i| Request::new(0, i % 10, STEPS, 7_000 + i as u64))
        .collect()
}

fn fnv64(data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in data {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

struct RunResult {
    wall_s: f64,
    /// Client-observed completion latency (dispatch → response), which
    /// includes queue wait — the quantity stealing actually improves.
    latencies: Vec<f64>,
    checksums: Vec<u64>,
    shed: u64,
    report: PoolReport,
}

fn run_pool_with(specs: Vec<SimSpec>, route: RoutePolicy,
                 steal: bool) -> RunResult {
    let rebalancer = steal.then(|| Rebalancer::new(STEAL_WINDOW));
    let handles: Vec<ReplicaHandle> = specs
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            ReplicaHandle::spawn_with(i, 4096, SimEngine::factory(s),
                                      rebalancer.clone())
            .unwrap()
        })
        .collect();
    let router = Router::with_rebalancer(handles, route, 4096, rebalancer);
    let t0 = Instant::now();
    // one collector thread per request so completion timestamps are
    // observed the moment each response lands, not in dispatch order
    let mut joins = Vec::with_capacity(REQUESTS);
    for req in workload() {
        let (tx, rx) = mpsc::channel();
        assert!(router.dispatch(req, tx), "closed-loop run must not shed");
        joins.push(std::thread::spawn(move || {
            let res = rx.recv().expect("response");
            (t0.elapsed().as_secs_f64(), fnv64(res.image.data()))
        }));
    }
    let mut latencies = Vec::with_capacity(REQUESTS);
    let mut checksums = Vec::with_capacity(REQUESTS);
    for j in joins {
        let (lat, sum) = j.join().expect("collector");
        latencies.push(lat);
        checksums.push(sum);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = router.shutdown();
    checksums.sort_unstable();
    RunResult { wall_s, latencies, checksums, shed: report.shed, report }
}

fn run_pool(replicas: usize, route: RoutePolicy) -> RunResult {
    run_pool_with(vec![spec(); replicas], route, false)
}

fn row(label: &str, r: &RunResult) -> String {
    format!(
        "  {:<16} {:>9.1} req/s   p50 {:>8.2}ms   p95 {:>8.2}ms   ({} shed)",
        label,
        REQUESTS as f64 / r.wall_s,
        1e3 * quantile(&r.latencies, 0.5),
        1e3 * quantile(&r.latencies, 0.95),
        r.shed,
    )
}

/// The skewed-Γ scenario: half the pool never skips (Γ=0), half skips
/// aggressively (Γ≈90%). jsq balances *queue lengths* at admission, so
/// without stealing the slow replica strands ~half the workload; with
/// stealing the fast replica pulls the slow one's queued jobs as it
/// goes idle. Returns (p95 without stealing, p95 with stealing).
fn skewed_gamma_scenario() -> (f64, f64) {
    let specs = || vec![SimSpec::with_lazy(0, WORK),
                        SimSpec::with_lazy(90, WORK)];
    println!("skewed-Γ scenario (2 replicas, Γ = 0% vs 90%, route jsq):");
    let base = run_pool_with(specs(), RoutePolicy::Jsq, false);
    println!("{}", row("jsq", &base));
    let stealing = run_pool_with(specs(), RoutePolicy::Jsq, true);
    println!("{}", row("jsq + steal", &stealing));
    for r in &stealing.report.replicas {
        println!("    replica {} ({:<8}): served {:>3}, stole {:>3}, \
                  lost {:>3}",
                 r.id, r.policy, r.serve.completed, r.steals, r.stolen);
    }
    let (steals, stolen) = (stealing.report.total_steals(),
                            stealing.report.total_stolen());
    assert_eq!(steals, stolen,
               "migration conservation: every steal has one thief and \
                one victim");
    assert_eq!(
        stealing.report.completed() + base.report.completed(),
        2 * REQUESTS,
        "no job lost or duplicated across either run"
    );
    let p95_base = quantile(&base.latencies, 0.95);
    let p95_steal = quantile(&stealing.latencies, 0.95);
    (p95_base, p95_steal)
}

fn main() {
    lazydit::util::logging::init();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let mut sweep: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&n| n <= cores.max(2)).collect();
    if sweep.is_empty() {
        sweep.push(1);
    }

    // reference checksums straight from the deterministic image function
    let elems = spec().img_elems;
    let mut reference: Vec<u64> = workload()
        .iter()
        .map(|req| fnv64(sim_image(req, elems).data()))
        .collect();
    reference.sort_unstable();

    println!(
        "pool_scaling: {REQUESTS} requests × {STEPS} steps, Γ target \
         {LAZY_PCT}%, work/module {WORK} ({cores} cores)\n"
    );
    println!("replica sweep (route jsq):");
    let mut base_rps = 0.0f64;
    let mut widest_rps = 0.0f64;
    let mut deterministic = true;
    for &n in &sweep {
        let r = run_pool(n, RoutePolicy::Jsq);
        println!("{}", row(&format!("{n} replica(s)"), &r));
        deterministic &= r.checksums == reference;
        let rps = REQUESTS as f64 / r.wall_s;
        if n == 1 {
            base_rps = rps;
        }
        widest_rps = rps;
    }

    let widest = *sweep.last().unwrap();
    println!("\nrouting policies at {widest} replica(s):");
    for route in [RoutePolicy::RoundRobin, RoutePolicy::Jsq, RoutePolicy::Lazy] {
        let r = run_pool(widest, route);
        println!("{}", row(route.name(), &r));
        deterministic &= r.checksums == reference;
    }

    println!("\nwork stealing at {widest} replica(s) (uniform Γ):");
    for steal in [false, true] {
        let r = run_pool_with(vec![spec(); widest], RoutePolicy::Jsq, steal);
        println!("{}", row(if steal { "jsq + steal" } else { "jsq" }, &r));
        deterministic &= r.checksums == reference;
    }

    println!();
    let (p95_base, p95_steal) = skewed_gamma_scenario();

    println!();
    if deterministic {
        println!("determinism: OK — image bytes identical across every pool \
                  shape, routing policy, and steal mode");
    } else {
        println!("determinism: FAILED — outputs diverged across runs");
    }
    if widest > 1 {
        let speedup = widest_rps / base_rps.max(1e-9);
        println!("scaling: {widest} replicas at {speedup:.2}× the 1-replica \
                  throughput{}",
                 if speedup > 1.2 { " — OK" } else { " — WEAK (loaded machine?)" });
    }
    println!(
        "stealing under skewed Γ: p95 {:.2}ms → {:.2}ms{}",
        1e3 * p95_base,
        1e3 * p95_steal,
        if p95_steal < p95_base {
            " — OK (strictly lower)"
        } else {
            " — WEAK (expected stealing to beat static jsq; loaded machine?)"
        }
    );
    if !deterministic {
        std::process::exit(1);
    }
}
