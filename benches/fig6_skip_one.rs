//! Bench: regenerate paper Figure 6 / Appendix A.4 (with jointly-trained
//! gates, skip only MHSA or only FFN at inference).

fn main() {
    let argv = vec![
        "fig6".to_string(),
        "--steps".into(), "20".into(),
        "--lazy".into(), "50".into(),
        "--n-eval".into(), "32".into(),
        "--n-real".into(), "160".into(),
    ];
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("fig6 bench failed: {e:#}");
        std::process::exit(1);
    }
}
