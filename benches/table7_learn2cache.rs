//! Bench: regenerate paper Table 7 (LazyDiT's input-dynamic gates vs the
//! input-independent Learn2Cache-analog static schedule at equal compute).

fn main() {
    let full = std::env::var("LAZYDIT_BENCH_FULL").is_ok();
    let mut argv = vec![
        "table7".to_string(),
        "--n-eval".into(), "48".into(),
        "--n-real".into(), "128".into(),
    ];
    if !full {
        argv.push("--quick".into());
    }
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("table7 bench failed: {e:#}");
        std::process::exit(1);
    }
}
