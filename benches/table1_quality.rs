//! Bench: regenerate paper Table 1 (quality vs DDIM across steps / lazy
//! ratios) on the DiT-XL/2-256 analog. `cargo bench --bench table1_quality`.
//!
//! Env: LAZYDIT_BENCH_FULL=1 for the full row set (default: quick subset);
//!      LAZYDIT_BENCH_CONFIG to change the model config.

fn main() {
    let full = std::env::var("LAZYDIT_BENCH_FULL").is_ok();
    let config = std::env::var("LAZYDIT_BENCH_CONFIG")
        .unwrap_or_else(|_| "xl-256a".into());
    let mut argv = vec![
        "table1".to_string(),
        "--config".into(), config,
        "--n-eval".into(), "48".into(),
        "--n-real".into(), "128".into(),
    ];
    if !full {
        argv.push("--quick".into());
    }
    if let Err(e) = lazydit::cli::dispatch(&argv) {
        eprintln!("table1 bench failed: {e:#}");
        std::process::exit(1);
    }
}
